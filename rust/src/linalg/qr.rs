//! Householder QR and least-squares solves.
//!
//! OMP / CoSaMP / StoGradMP repeatedly solve small overdetermined systems
//! `min ||A_T z - y||` where `A_T` is the `m x k` submatrix of selected
//! columns (`k <= 3s << m`). Householder QR is backward-stable and cheap at
//! these sizes; the factorization is in-place and the solve reuses it.

use super::dense::Mat;
use super::scalar::Scalar;

/// In-place Householder QR factorization of an `m x k` matrix (`m >= k`).
///
/// After construction, `R` occupies the upper triangle of `a` and the
/// Householder vectors live below the diagonal (LAPACK `geqrf` layout) with
/// their scaling factors in `tau`.
pub struct Qr<S: Scalar> {
    a: Mat<S>,
    tau: Vec<S>,
}

impl<S: Scalar> Qr<S> {
    /// Factor `a` (consumed). Panics if `rows < cols`.
    pub fn factor(mut a: Mat<S>) -> Self {
        let m = a.rows();
        let k = a.cols();
        assert!(m >= k, "QR requires rows >= cols (got {m} x {k})");
        let mut tau = vec![S::ZERO; k];
        for j in 0..k {
            // Householder vector for column j, rows j..m.
            let mut norm2 = S::ZERO;
            for i in j..m {
                let v = a.get(i, j);
                norm2 += v * v;
            }
            let norm = norm2.sqrt();
            if norm == S::ZERO {
                tau[j] = S::ZERO;
                continue;
            }
            let a_jj = a.get(j, j);
            // alpha = -sign(a_jj) * ||col|| avoids cancellation.
            let alpha = if a_jj >= S::ZERO { -norm } else { norm };
            let v0 = a_jj - alpha;
            // Normalize so v[j] = 1 implicitly; store v[i]/v0 below diag.
            for i in (j + 1)..m {
                let v = a.get(i, j) / v0;
                a.set(i, j, v);
            }
            // tau = (alpha - a_jj)/alpha ... standard: tau = v0 / -alpha? Use
            // tau = 2 / (1 + sum_{i>j} v_i^2) with v_j = 1.
            let mut vtv = S::ONE;
            for i in (j + 1)..m {
                let v = a.get(i, j);
                vtv += v * v;
            }
            let t = S::from_f64(2.0) / vtv;
            tau[j] = t;
            a.set(j, j, alpha);
            // Apply H_j = I - tau v v^T to the trailing columns.
            for c in (j + 1)..k {
                // w = v^T a[:, c] (v_j = 1)
                let mut w = a.get(j, c);
                for i in (j + 1)..m {
                    w += a.get(i, j) * a.get(i, c);
                }
                w *= t;
                let prev = a.get(j, c);
                a.set(j, c, prev - w);
                for i in (j + 1)..m {
                    let prev = a.get(i, c);
                    let vij = a.get(i, j);
                    a.set(i, c, prev - w * vij);
                }
            }
        }
        Qr { a, tau }
    }

    /// Number of columns (solution length).
    pub fn k(&self) -> usize {
        self.a.cols()
    }

    /// Apply `Q^T` to `rhs` in place (length `m`).
    fn apply_qt(&self, rhs: &mut [S]) {
        let m = self.a.rows();
        let k = self.a.cols();
        assert_eq!(rhs.len(), m);
        for j in 0..k {
            let t = self.tau[j];
            if t == S::ZERO {
                continue;
            }
            let mut w = rhs[j];
            for i in (j + 1)..m {
                w += self.a.get(i, j) * rhs[i];
            }
            w *= t;
            rhs[j] -= w;
            for i in (j + 1)..m {
                let vij = self.a.get(i, j);
                rhs[i] -= w * vij;
            }
        }
    }

    /// Solve `min ||A z - y||_2` (least squares). Returns `z` of length `k`.
    ///
    /// Rank-deficient columns (|R_jj| below `EPS * max|R|`) get `z_j = 0` —
    /// OMP can momentarily select nearly-dependent columns on noisy data and
    /// must not blow up.
    pub fn solve(&self, y: &[S]) -> Vec<S> {
        let mut rhs = Vec::new();
        let mut z = Vec::new();
        self.solve_into(y, &mut rhs, &mut z);
        z
    }

    /// Allocation-free form of [`Qr::solve`]: the `Q^T y` work happens in
    /// `rhs` and the solution is written into `z` (both cleared and
    /// resized) — identical arithmetic, reused buffers for hot loops.
    pub fn solve_into(&self, y: &[S], rhs: &mut Vec<S>, z: &mut Vec<S>) {
        let m = self.a.rows();
        let k = self.a.cols();
        assert_eq!(y.len(), m, "rhs length");
        rhs.clear();
        rhs.extend_from_slice(y);
        self.apply_qt(rhs);
        // Back-substitute R z = rhs[0..k].
        let mut rmax = S::ZERO;
        for j in 0..k {
            rmax = rmax.max_s(self.a.get(j, j).abs());
        }
        let tol = rmax * S::EPS * S::from_f64(64.0);
        z.clear();
        z.resize(k, S::ZERO);
        for j in (0..k).rev() {
            let mut v = rhs[j];
            for c in (j + 1)..k {
                v -= self.a.get(j, c) * z[c];
            }
            let d = self.a.get(j, j);
            z[j] = if d.abs() <= tol { S::ZERO } else { v / d };
        }
    }

    /// Consume the factorization, reclaiming the matrix storage (packed
    /// `R` + Householder vectors) so callers can reuse the buffer.
    pub fn into_matrix(self) -> Mat<S> {
        self.a
    }
}

/// Convenience: least-squares solve `min ||a z - y||`.
///
/// Overdetermined systems (`rows >= cols`) use Householder QR;
/// underdetermined ones (which CoSaMP/StoGradMP can produce when the merged
/// support outgrows `m` at very low sampling rates) fall back to CGLS,
/// whose iterates stay in the row space (minimum-norm solution).
pub fn lstsq<S: Scalar>(a: &Mat<S>, y: &[S]) -> Vec<S> {
    if a.rows() >= a.cols() {
        Qr::factor(a.clone()).solve(y)
    } else {
        super::cgls::cgls(a, y, S::from_f64(1e-12), 4 * a.rows().max(8)).z
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::dense::{dist2, nrm2};
    use crate::rng::Rng;

    fn rand_mat(rng: &mut Rng, m: usize, k: usize) -> Mat<f64> {
        Mat::from_fn(m, k, |_, _| rng.gauss())
    }

    #[test]
    fn solves_square_system_exactly() {
        let a = Mat::from_vec(2, 2, vec![2.0f64, 1.0, 1.0, 3.0]);
        let z = lstsq(&a, &[5.0, 10.0]);
        // 2x + y = 5; x + 3y = 10 -> x = 1, y = 3
        assert!((z[0] - 1.0).abs() < 1e-12);
        assert!((z[1] - 3.0).abs() < 1e-12);
    }

    #[test]
    fn recovers_planted_solution_overdetermined() {
        let mut rng = Rng::seed_from(7);
        for &(m, k) in &[(10usize, 3usize), (40, 10), (100, 25)] {
            let a = rand_mat(&mut rng, m, k);
            let z_true: Vec<f64> = (0..k).map(|_| rng.gauss()).collect();
            let y = a.gemv(&z_true);
            let z = lstsq(&a, &y);
            assert!(dist2(&z, &z_true) < 1e-9, "m={m} k={k}");
        }
    }

    #[test]
    fn residual_is_orthogonal_to_columns() {
        let mut rng = Rng::seed_from(42);
        let (m, k) = (30, 8);
        let a = rand_mat(&mut rng, m, k);
        let y: Vec<f64> = (0..m).map(|_| rng.gauss()).collect();
        let z = lstsq(&a, &y);
        let az = a.gemv(&z);
        let r: Vec<f64> = y.iter().zip(&az).map(|(&p, &q)| p - q).collect();
        // A^T r == 0 at the least-squares optimum.
        let atr = a.gemv_t(&r);
        assert!(nrm2(&atr) < 1e-9 * nrm2(&y), "normal equations violated");
    }

    #[test]
    fn rank_deficient_does_not_blow_up() {
        // Two identical columns.
        let a = Mat::from_vec(3, 2, vec![1.0f64, 1.0, 2.0, 2.0, 3.0, 3.0]);
        let z = lstsq(&a, &[1.0, 2.0, 3.0]);
        assert!(z.iter().all(|v| v.is_finite()));
        // The reachable residual is zero: a z should equal y via one column.
        let az = a.gemv(&z);
        assert!(dist2(&az, &[1.0, 2.0, 3.0]) < 1e-9);
    }

    #[test]
    #[should_panic(expected = "rows >= cols")]
    fn underdetermined_qr_panics() {
        let a = Mat::<f64>::zeros(2, 3);
        let _ = Qr::factor(a);
    }

    #[test]
    fn underdetermined_lstsq_falls_back_to_cgls() {
        // 2 x 4 system with an exact solution: residual must vanish.
        let mut rng = Rng::seed_from(3);
        let a = rand_mat(&mut rng, 2, 4);
        let z0: Vec<f64> = (0..4).map(|_| rng.gauss()).collect();
        let y = a.gemv(&z0);
        let z = lstsq(&a, &y);
        let az = a.gemv(&z);
        assert!(dist2(&az, &y) < 1e-8, "residual {}", dist2(&az, &y));
    }

    #[test]
    fn f32_path_works() {
        let a = Mat::from_vec(3, 2, vec![1.0f32, 0.0, 0.0, 1.0, 1.0, 1.0]);
        let z = lstsq(&a, &[1.0, 2.0, 3.1]);
        assert!(z.iter().all(|v| v.is_finite()));
    }
}
