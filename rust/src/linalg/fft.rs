//! In-crate O(n log n) orthogonal transforms: an iterative, pair-fused
//! radix-4 complex FFT and the DCT-II / DCT-III pair built on it.
//!
//! This is the compute core of the matrix-free subsampled-DCT measurement
//! operator ([`super::measure::SubsampledDctOp`]): a row of the `n x n`
//! DCT-II matrix never needs to exist — `A x` is one fast DCT-II followed by
//! an `m`-row gather, and `A^T r` is a scatter followed by one fast DCT-III
//! (the exact transpose). Zero dependencies, like the hand-rolled TOML/JSON
//! layers; the plan precomputes twiddle, phase, and bit-reversal tables once
//! so the per-transform passes are pure streaming arithmetic.
//!
//! ## The fast path, and its parity contract
//!
//! The FFT runs the classic radix-2 DIT stage schedule with **consecutive
//! stage pairs fused into radix-4 passes**: one sweep over the array applies
//! the span-`2h` and span-`4h` butterflies together, reading the *same*
//! twiddle-table entries (`tw[k·n/(2h)]`, `tw[k·n/(4h)]`, `tw[(h+k)·n/(4h)]`)
//! and evaluating the *same* per-output floating-point expressions as two
//! separate radix-2 passes would. Fusion halves the number of memory sweeps
//! — the actual bottleneck at `n = 2^17 … 2^20`, where one complex lane pair
//! is 2–16 MB and every stage is a cache-cold pass — without touching any
//! rounding. On top of that, the stages with span ≤ the L2-sized block run
//! depth-first inside each block (pass order across disjoint blocks cannot
//! affect arithmetic). The pre-fusion pipeline is retained as
//! [`DctPlan::dct2_reference_into`] / [`DctPlan::dct3_reference_into`]: the
//! measured baseline of the `transforms` benches, and the anchor of the
//! **bit-for-bit** parity pin in `rust/tests/simd_parity.rs`. This is
//! stronger than the crate-wide ≤ 1e-12 relative-tolerance allowance for
//! documented reassociation — the fused path does not reassociate anything.
//!
//! ## Plan cache
//!
//! Plans are immutable after construction and ~28 bytes/point (`24n` bytes
//! of twiddle + phase tables plus a `4n`-byte bit-reversal table — ~28 MiB
//! at `n = 2^20`), so [`plan_for`] keeps a small process-wide LRU of
//! `Arc<DctPlan>` keyed by `n`. Repeat traffic — the serve front-end's
//! operator-cache misses, pool rebuilds, back-to-back trials — shares one
//! table build per size instead of redoing O(n) trig per construction.
//!
//! Conventions (unnormalized, matching the direct sums the dense
//! `PartialDct` ensemble evaluates):
//!
//! * DCT-II:  `X_k = Σ_{j<n} x_j · cos(π k (2j+1) / (2n))`
//! * DCT-III: `x_j = Σ_{k<n} X_k · cos(π k (2j+1) / (2n))` — the *transpose*
//!   of DCT-II (not its scaled inverse; the `c0` orthonormalization lives in
//!   the operator's per-row scales).
//!
//! Sizes are restricted to powers of two (the generated benchmarks choose
//! `n = 2^17 … 2^20`; a mixed-radix fallback would buy nothing here). The
//! DCT-II is computed via Makhoul's N-point FFT mapping (no 2n
//! zero-padding): reorder the input as `v_j = x_{2j}`, `v_{n-1-j} =
//! x_{2j+1}`, run one complex FFT, and take `X_k = Re(e^{-iπk/(2n)} V_k)`.
//! The DCT-III is the algebraic transpose of that pipeline (diagonal
//! multiply → FFT → inverse reorder), which is what makes the operator's
//! adjoint property hold to rounding error.

use crate::sync::{Arc, Mutex};

/// Precomputed tables for size-`n` transforms (`n` a power of two).
///
/// Memory: `28 n` bytes — `1.5 n` complex table entries (twiddles + phases,
/// `24 n` bytes) plus the `u32` bit-reversal permutation (`4 n` bytes). At
/// `n = 2^20` about 28 MiB, against the 2.4 TB an `m x n` dense matrix
/// would need at the `large_n` bench shape — and built once per size when
/// obtained through [`plan_for`].
#[derive(Clone, Debug)]
pub struct DctPlan {
    n: usize,
    /// FFT twiddles `e^{-2πi j / n}`, `j < n/2`.
    tw_re: Vec<f64>,
    tw_im: Vec<f64>,
    /// DCT phase factors `e^{-iπ k / (2n)}`, `k < n`.
    ph_re: Vec<f64>,
    ph_im: Vec<f64>,
    /// Bit-reversal permutation (`bitrev[i]` = `i` with its `lg n` low bits
    /// reversed), precomputed so the permutation pass is a table walk
    /// instead of per-index bit arithmetic.
    bitrev: Vec<u32>,
}

/// Reusable complex workspace for one plan (two `n`-length lanes). One per
/// caller (kernels hold their own), so concurrent workers never contend.
#[derive(Clone, Debug, Default)]
pub struct DctScratch {
    re: Vec<f64>,
    im: Vec<f64>,
}

/// Bounded process-wide cache of built plans, most-recently-used first.
/// Four plans cover every size a serve process realistically alternates
/// between (at the jumbo `n = 2^20` that is ~112 MiB worst case); the cap
/// exists so a size sweep cannot grow the process without bound.
const PLAN_CACHE_CAP: usize = 4;

static PLAN_CACHE: Mutex<Vec<Arc<DctPlan>>> = Mutex::new(Vec::new());

/// Shared plan for size `n` (a power of two — panics otherwise, like
/// [`DctPlan::new`]): returns the cached `Arc<DctPlan>` when one exists,
/// building and inserting it otherwise. The table build runs *outside* the
/// cache lock, so a large first-time build never stalls other sizes; if two
/// threads race on the same fresh `n`, the loser adopts the winner's plan.
pub fn plan_for(n: usize) -> Arc<DctPlan> {
    let mut cache = PLAN_CACHE.lock().unwrap_or_else(|e| e.into_inner());
    if let Some(pos) = cache.iter().position(|p| p.n == n) {
        let plan = cache.remove(pos);
        cache.insert(0, Arc::clone(&plan));
        return plan;
    }
    drop(cache);
    let plan = Arc::new(DctPlan::new(n));
    let mut cache = PLAN_CACHE.lock().unwrap_or_else(|e| e.into_inner());
    if let Some(pos) = cache.iter().position(|p| p.n == n) {
        let racer = cache.remove(pos);
        cache.insert(0, Arc::clone(&racer));
        return racer;
    }
    cache.insert(0, Arc::clone(&plan));
    cache.truncate(PLAN_CACHE_CAP);
    plan
}

impl DctPlan {
    /// Build tables for size `n`. Panics unless `n` is a power of two.
    /// Prefer [`plan_for`] on any path that may rebuild sizes.
    pub fn new(n: usize) -> Self {
        assert!(n.is_power_of_two(), "DctPlan: n = {n} must be a power of two");
        let half = n / 2;
        let mut tw_re = Vec::with_capacity(half);
        let mut tw_im = Vec::with_capacity(half);
        for j in 0..half {
            let theta = -2.0 * std::f64::consts::PI * j as f64 / n as f64;
            tw_re.push(theta.cos());
            tw_im.push(theta.sin());
        }
        let mut ph_re = Vec::with_capacity(n);
        let mut ph_im = Vec::with_capacity(n);
        for k in 0..n {
            let theta = -std::f64::consts::PI * k as f64 / (2.0 * n as f64);
            ph_re.push(theta.cos());
            ph_im.push(theta.sin());
        }
        let mut bitrev = vec![0u32; n];
        for i in 1..n {
            bitrev[i] = (bitrev[i >> 1] >> 1) | if i & 1 == 1 { (n as u32) >> 1 } else { 0 };
        }
        DctPlan { n, tw_re, tw_im, ph_re, ph_im, bitrev }
    }

    /// Transform size.
    #[inline(always)]
    pub fn n(&self) -> usize {
        self.n
    }

    /// Fresh workspace sized for this plan.
    pub fn scratch(&self) -> DctScratch {
        DctScratch { re: vec![0.0; self.n], im: vec![0.0; self.n] }
    }

    fn check_scratch<'a>(&self, s: &'a mut DctScratch) -> (&'a mut [f64], &'a mut [f64]) {
        s.re.resize(self.n, 0.0);
        s.im.resize(self.n, 0.0);
        (&mut s.re, &mut s.im)
    }

    /// Table-driven bit-reversal permutation of both lanes.
    fn bit_reverse(&self, re: &mut [f64], im: &mut [f64]) {
        for (i, &jr) in self.bitrev.iter().enumerate() {
            let j = jr as usize;
            if i < j {
                re.swap(i, j);
                im.swap(i, j);
            }
        }
    }

    /// One classic radix-2 DIT stage of span `len` over the region
    /// `[r0, r0 + rlen)` (the region is a whole number of `len`-blocks).
    /// Twiddle for offset `k` is `e^{-2πi k/len} = tw[k·(n/len)]`.
    fn radix2_stage(&self, re: &mut [f64], im: &mut [f64], r0: usize, rlen: usize, len: usize) {
        let half = len / 2;
        let step = self.n / len;
        let end = r0 + rlen;
        let mut base = r0;
        while base < end {
            for k in 0..half {
                let wr = self.tw_re[k * step];
                let wi = self.tw_im[k * step];
                let (ur, ui) = (re[base + k], im[base + k]);
                let (xr, xi) = (re[base + k + half], im[base + k + half]);
                let vr = xr * wr - xi * wi;
                let vi = xr * wi + xi * wr;
                re[base + k] = ur + vr;
                im[base + k] = ui + vi;
                re[base + k + half] = ur - vr;
                im[base + k + half] = ui - vi;
            }
            base += len;
        }
    }

    /// The fused pair of radix-2 stages with spans `2h` and `4h` over
    /// `[r0, r0 + rlen)`: per quarter-offset `k < h` this applies both
    /// span-`2h` butterflies and the two span-`4h` butterflies (offsets `k`
    /// and `h + k`) that consume their outputs, in one sweep. Same table
    /// reads, same expressions, same values as the two separate stages —
    /// only the number of memory passes changes, so the result is
    /// bit-identical to [`DctPlan::radix2_stage`] run twice.
    fn radix4_pair(&self, re: &mut [f64], im: &mut [f64], r0: usize, rlen: usize, h: usize) {
        let step_a = self.n / (2 * h);
        let step_b = self.n / (4 * h);
        let end = r0 + rlen;
        let mut q0 = r0;
        while q0 < end {
            let (q1, q2, q3) = (q0 + h, q0 + 2 * h, q0 + 3 * h);
            for k in 0..h {
                let (war, wai) = (self.tw_re[k * step_a], self.tw_im[k * step_a]);
                // span-2h butterfly on quarters 0|1:
                let (ur, ui) = (re[q0 + k], im[q0 + k]);
                let (xr, xi) = (re[q1 + k], im[q1 + k]);
                let vr = xr * war - xi * wai;
                let vi = xr * wai + xi * war;
                let (p0r, p0i) = (ur + vr, ui + vi);
                let (p1r, p1i) = (ur - vr, ui - vi);
                // span-2h butterfly on quarters 2|3 (same twiddle):
                let (ur, ui) = (re[q2 + k], im[q2 + k]);
                let (xr, xi) = (re[q3 + k], im[q3 + k]);
                let vr = xr * war - xi * wai;
                let vi = xr * wai + xi * war;
                let (p2r, p2i) = (ur + vr, ui + vi);
                let (p3r, p3i) = (ur - vr, ui - vi);
                // span-4h butterfly at offset k (twiddle straight from the
                // table — not a derived rotation, to keep bits identical):
                let (wbr, wbi) = (self.tw_re[k * step_b], self.tw_im[k * step_b]);
                let vr = p2r * wbr - p2i * wbi;
                let vi = p2r * wbi + p2i * wbr;
                re[q0 + k] = p0r + vr;
                im[q0 + k] = p0i + vi;
                re[q2 + k] = p0r - vr;
                im[q2 + k] = p0i - vi;
                // span-4h butterfly at offset h + k:
                let (wcr, wci) = (self.tw_re[(h + k) * step_b], self.tw_im[(h + k) * step_b]);
                let vr = p3r * wcr - p3i * wci;
                let vi = p3r * wci + p3i * wcr;
                re[q1 + k] = p1r + vr;
                im[q1 + k] = p1i + vi;
                re[q3 + k] = p1r - vr;
                im[q3 + k] = p1i - vi;
            }
            q0 += 4 * h;
        }
    }

    /// Run the stage schedule covering spans `(lo, hi]` over the region
    /// `[r0, r0 + rlen)`, fusing stage pairs into radix-4 passes (one
    /// leading radix-2 stage soaks up an odd stage count). Executes exactly
    /// the butterflies of `radix2_stage` at spans `2·lo, 4·lo, …, hi`.
    fn stages(&self, re: &mut [f64], im: &mut [f64], r0: usize, rlen: usize, lo: usize, hi: usize) {
        let mut h = lo;
        if (hi / lo).trailing_zeros() % 2 == 1 {
            self.radix2_stage(re, im, r0, rlen, 2 * h);
            h *= 2;
        }
        while 4 * h <= hi {
            self.radix4_pair(re, im, r0, rlen, h);
            h *= 4;
        }
    }

    /// Chunk size for the depth-first phase of [`DctPlan::fft`]: 2^12 or
    /// 2^13 complex points (64–128 KB per f64 lane pair) stays L2-resident;
    /// the choice is parity-matched to `lg n` so the chunk-local stage
    /// schedule is an exact prefix of the global one (the radix-4 pairing
    /// lines up at the chunk boundary).
    fn cache_block(&self) -> usize {
        if self.n.trailing_zeros() % 2 == 0 {
            1 << 12
        } else {
            1 << 13
        }
    }

    /// In-place iterative FFT with the `e^{-2πi jk/n}` sign convention:
    /// table-driven bit reversal, then the pair-fused radix-4 schedule —
    /// depth-first inside L2-sized chunks for the short spans, then the
    /// remaining global spans. Bit-identical to [`DctPlan::fft_reference`]
    /// (stage order across disjoint chunks is arithmetic-neutral; fusion
    /// changes pass count, not expressions).
    fn fft(&self, re: &mut [f64], im: &mut [f64]) {
        let n = self.n;
        debug_assert_eq!(re.len(), n);
        debug_assert_eq!(im.len(), n);
        if n == 1 {
            return;
        }
        self.bit_reverse(re, im);
        let cb = self.cache_block();
        if cb < n {
            let mut c0 = 0;
            while c0 < n {
                self.stages(re, im, c0, cb, 1, cb);
                c0 += cb;
            }
            self.stages(re, im, 0, n, cb, n);
        } else {
            self.stages(re, im, 0, n, 1, n);
        }
    }

    /// The pre-fusion pipeline — one radix-2 pass per stage, no chunking —
    /// retained as the measured baseline of the `transforms` benches and
    /// the parity anchor: [`DctPlan::fft`] must reproduce it bit-for-bit.
    fn fft_reference(&self, re: &mut [f64], im: &mut [f64]) {
        let n = self.n;
        debug_assert_eq!(re.len(), n);
        debug_assert_eq!(im.len(), n);
        if n == 1 {
            return;
        }
        self.bit_reverse(re, im);
        let mut len = 2usize;
        while len <= n {
            self.radix2_stage(re, im, 0, n, len);
            len <<= 1;
        }
    }

    fn dct2_core(&self, x: &[f64], scratch: &mut DctScratch, out: &mut [f64], reference: bool) {
        let n = self.n;
        assert_eq!(x.len(), n, "dct2: input length");
        assert_eq!(out.len(), n, "dct2: output length");
        if n == 1 {
            out[0] = x[0];
            return;
        }
        let (re, im) = self.check_scratch(scratch);
        // Makhoul reorder: v_j = x_{2j}, v_{n-1-j} = x_{2j+1}.
        for j in 0..n / 2 {
            re[j] = x[2 * j];
            re[n - 1 - j] = x[2 * j + 1];
        }
        im.fill(0.0);
        if reference {
            self.fft_reference(re, im);
        } else {
            self.fft(re, im);
        }
        // X_k = Re(e^{-iπk/(2n)} V_k).
        for k in 0..n {
            out[k] = self.ph_re[k] * re[k] - self.ph_im[k] * im[k];
        }
    }

    fn dct3_core(&self, r: &[f64], scratch: &mut DctScratch, out: &mut [f64], reference: bool) {
        let n = self.n;
        assert_eq!(r.len(), n, "dct3: input length");
        assert_eq!(out.len(), n, "dct3: output length");
        if n == 1 {
            out[0] = r[0];
            return;
        }
        let (re, im) = self.check_scratch(scratch);
        for k in 0..n {
            re[k] = self.ph_re[k] * r[k];
            im[k] = self.ph_im[k] * r[k];
        }
        if reference {
            self.fft_reference(re, im);
        } else {
            self.fft(re, im);
        }
        // Inverse of the Makhoul reorder (the permutation's transpose).
        for j in 0..n / 2 {
            out[2 * j] = re[j];
            out[2 * j + 1] = re[n - 1 - j];
        }
    }

    /// Unnormalized DCT-II: `out[k] = Σ_j x[j] cos(π k (2j+1) / (2n))`.
    pub fn dct2_into(&self, x: &[f64], scratch: &mut DctScratch, out: &mut [f64]) {
        self.dct2_core(x, scratch, out, false);
    }

    /// Unnormalized DCT-III — the exact transpose of [`DctPlan::dct2_into`]:
    /// `out[j] = Σ_k r[k] cos(π k (2j+1) / (2n))`. Implemented as the
    /// reversed pipeline (phase multiply → FFT → inverse reorder), so
    /// `⟨dct2(x), r⟩ = ⟨x, dct3(r)⟩` holds to rounding error.
    pub fn dct3_into(&self, r: &[f64], scratch: &mut DctScratch, out: &mut [f64]) {
        self.dct3_core(r, scratch, out, false);
    }

    /// [`DctPlan::dct2_into`] on the retained radix-2 reference FFT —
    /// bit-identical output by the fusion argument above; exists to be
    /// measured against (old-vs-new `transforms` benches) and pinned
    /// against (`rust/tests/simd_parity.rs`).
    pub fn dct2_reference_into(&self, x: &[f64], scratch: &mut DctScratch, out: &mut [f64]) {
        self.dct2_core(x, scratch, out, true);
    }

    /// [`DctPlan::dct3_into`] on the reference FFT (see
    /// [`DctPlan::dct2_reference_into`]).
    pub fn dct3_reference_into(&self, r: &[f64], scratch: &mut DctScratch, out: &mut [f64]) {
        self.dct3_core(r, scratch, out, true);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn direct_dct2(x: &[f64]) -> Vec<f64> {
        let n = x.len();
        let nf = n as f64;
        (0..n)
            .map(|k| {
                (0..n)
                    .map(|j| {
                        x[j] * (std::f64::consts::PI * k as f64 * (j as f64 + 0.5) / nf).cos()
                    })
                    .sum()
            })
            .collect()
    }

    fn direct_dct3(r: &[f64]) -> Vec<f64> {
        let n = r.len();
        let nf = n as f64;
        (0..n)
            .map(|j| {
                (0..n)
                    .map(|k| {
                        r[k] * (std::f64::consts::PI * k as f64 * (j as f64 + 0.5) / nf).cos()
                    })
                    .sum()
            })
            .collect()
    }

    fn wave(n: usize, seed: u64) -> Vec<f64> {
        (0..n).map(|i| ((i as f64 + 1.3 * seed as f64) * 0.7129).sin()).collect()
    }

    #[test]
    fn dct2_matches_direct_sum_across_sizes() {
        for n in [1usize, 2, 4, 8, 16, 32, 128, 512] {
            let plan = DctPlan::new(n);
            let mut scratch = plan.scratch();
            let x = wave(n, 1);
            let mut out = vec![0.0; n];
            plan.dct2_into(&x, &mut scratch, &mut out);
            let want = direct_dct2(&x);
            for k in 0..n {
                assert!(
                    (out[k] - want[k]).abs() <= 1e-10 * (1.0 + want[k].abs()),
                    "n={n} k={k}: {} vs {}",
                    out[k],
                    want[k]
                );
            }
        }
    }

    #[test]
    fn dct3_matches_direct_sum_across_sizes() {
        for n in [1usize, 2, 4, 8, 64, 256] {
            let plan = DctPlan::new(n);
            let mut scratch = plan.scratch();
            let r = wave(n, 2);
            let mut out = vec![0.0; n];
            plan.dct3_into(&r, &mut scratch, &mut out);
            let want = direct_dct3(&r);
            for j in 0..n {
                assert!(
                    (out[j] - want[j]).abs() <= 1e-10 * (1.0 + want[j].abs()),
                    "n={n} j={j}: {} vs {}",
                    out[j],
                    want[j]
                );
            }
        }
    }

    #[test]
    fn fused_fft_matches_radix2_reference_bitwise() {
        // Sizes cover: odd and even lg n (the leading radix-2 stage vs pure
        // pairs), both at and past the cache-block boundary (4096/8192 run
        // unchunked, 16384/32768 exercise the depth-first phase split).
        for n in [2usize, 4, 8, 64, 512, 4096, 8192, 16384, 32768] {
            let plan = DctPlan::new(n);
            let mut s_new = plan.scratch();
            let mut s_ref = plan.scratch();
            let x = wave(n, 9);
            let mut out_new = vec![0.0; n];
            let mut out_ref = vec![0.0; n];
            plan.dct2_into(&x, &mut s_new, &mut out_new);
            plan.dct2_reference_into(&x, &mut s_ref, &mut out_ref);
            for k in 0..n {
                assert_eq!(out_new[k].to_bits(), out_ref[k].to_bits(), "dct2 n={n} k={k}");
            }
            plan.dct3_into(&x, &mut s_new, &mut out_new);
            plan.dct3_reference_into(&x, &mut s_ref, &mut out_ref);
            for j in 0..n {
                assert_eq!(out_new[j].to_bits(), out_ref[j].to_bits(), "dct3 n={n} j={j}");
            }
        }
    }

    #[test]
    fn dct3_is_the_transpose_of_dct2() {
        for n in [2usize, 8, 32, 256] {
            let plan = DctPlan::new(n);
            let mut scratch = plan.scratch();
            let x = wave(n, 3);
            let r = wave(n, 4);
            let mut fx = vec![0.0; n];
            plan.dct2_into(&x, &mut scratch, &mut fx);
            let mut ftr = vec![0.0; n];
            plan.dct3_into(&r, &mut scratch, &mut ftr);
            let lhs: f64 = fx.iter().zip(&r).map(|(&a, &b)| a * b).sum();
            let rhs: f64 = x.iter().zip(&ftr).map(|(&a, &b)| a * b).sum();
            assert!(
                (lhs - rhs).abs() <= 1e-10 * (1.0 + lhs.abs()),
                "n={n}: ⟨Fx,r⟩={lhs} vs ⟨x,Fᵀr⟩={rhs}"
            );
        }
    }

    #[test]
    fn dct2_of_delta_is_a_cosine_row() {
        // x = e_j ⇒ X_k = cos(πk(2j+1)/(2n)) — the j-th column of the
        // DCT-II matrix, which is how the operator's column gather and the
        // transform must agree.
        let n = 16;
        let plan = DctPlan::new(n);
        let mut scratch = plan.scratch();
        for j in [0usize, 1, 7, 15] {
            let mut x = vec![0.0; n];
            x[j] = 1.0;
            let mut out = vec![0.0; n];
            plan.dct2_into(&x, &mut scratch, &mut out);
            for k in 0..n {
                let want =
                    (std::f64::consts::PI * k as f64 * (j as f64 + 0.5) / n as f64).cos();
                assert!((out[k] - want).abs() < 1e-12, "j={j} k={k}");
            }
        }
    }

    #[test]
    fn orthogonality_roundtrip() {
        // DCT-III ∘ DCT-II = diag(n, n/2, ..., n/2) in the unnormalized
        // convention: x^T round-trips up to those known factors.
        let n = 64;
        let plan = DctPlan::new(n);
        let mut scratch = plan.scratch();
        let x = wave(n, 5);
        let mut fx = vec![0.0; n];
        plan.dct2_into(&x, &mut scratch, &mut fx);
        // Scale coefficient k by its inverse weight, transform back.
        let mut scaled = fx.clone();
        scaled[0] /= n as f64;
        for v in scaled.iter_mut().skip(1) {
            *v /= n as f64 / 2.0;
        }
        let mut back = vec![0.0; n];
        plan.dct3_into(&scaled, &mut scratch, &mut back);
        for j in 0..n {
            assert!((back[j] - x[j]).abs() < 1e-10, "j={j}: {} vs {}", back[j], x[j]);
        }
    }

    #[test]
    #[should_panic(expected = "power of two")]
    fn non_power_of_two_rejected() {
        let _ = DctPlan::new(12);
    }

    #[test]
    fn scratch_resizes_on_demand() {
        let plan = DctPlan::new(8);
        let mut scratch = DctScratch::default(); // empty — must self-size
        let x = wave(8, 6);
        let mut out = vec![0.0; 8];
        plan.dct2_into(&x, &mut scratch, &mut out);
        let want = direct_dct2(&x);
        for k in 0..8 {
            assert!((out[k] - want[k]).abs() < 1e-10);
        }
    }

    #[test]
    fn plan_cache_shares_then_evicts() {
        // Immediate repeat shares the same allocation. (The cache is
        // process-global; concurrent tests can only *add* entries, and
        // would need four distinct fresh sizes between these two calls to
        // perturb this.)
        let p1 = plan_for(64);
        let p2 = plan_for(64);
        assert!(Arc::ptr_eq(&p1, &p2), "repeat lookup must share the cached plan");
        assert_eq!(p1.n(), 64);
        // Evict: n = 2 is used by no other test through the cache; five
        // fresh distinct sizes afterwards must push it out of a cap-4 LRU.
        let first = plan_for(2);
        for n in [4usize, 8, 16, 32, 64] {
            let _ = plan_for(n);
        }
        let again = plan_for(2);
        assert!(!Arc::ptr_eq(&first, &again), "cap-{PLAN_CACHE_CAP} LRU must have evicted n=2");
        // The evicted-then-rebuilt plan still transforms identically.
        let mut s1 = first.scratch();
        let mut s2 = again.scratch();
        let x = wave(2, 7);
        let (mut o1, mut o2) = (vec![0.0; 2], vec![0.0; 2]);
        first.dct2_into(&x, &mut s1, &mut o1);
        again.dct2_into(&x, &mut s2, &mut o2);
        assert_eq!(o1[0].to_bits(), o2[0].to_bits());
        assert_eq!(o1[1].to_bits(), o2[1].to_bits());
    }

    #[test]
    #[should_panic(expected = "power of two")]
    fn plan_for_rejects_non_power_of_two() {
        let _ = plan_for(24);
    }
}
