//! In-crate O(n log n) orthogonal transforms: a radix-2 complex FFT and the
//! DCT-II / DCT-III pair built on it.
//!
//! This is the compute core of the matrix-free subsampled-DCT measurement
//! operator ([`super::measure::SubsampledDctOp`]): a row of the `n x n`
//! DCT-II matrix never needs to exist — `A x` is one fast DCT-II followed by
//! an `m`-row gather, and `A^T r` is a scatter followed by one fast DCT-III
//! (the exact transpose). Zero dependencies, like the hand-rolled TOML/JSON
//! layers; the plan precomputes twiddle and phase tables once so the
//! per-transform passes are pure streaming arithmetic.
//!
//! Conventions (unnormalized, matching the direct sums the dense
//! `PartialDct` ensemble evaluates):
//!
//! * DCT-II:  `X_k = Σ_{j<n} x_j · cos(π k (2j+1) / (2n))`
//! * DCT-III: `x_j = Σ_{k<n} X_k · cos(π k (2j+1) / (2n))` — the *transpose*
//!   of DCT-II (not its scaled inverse; the `c0` orthonormalization lives in
//!   the operator's per-row scales).
//!
//! Sizes are restricted to powers of two (radix-2 only — the recursion that
//! would cover arbitrary `n` buys nothing for the generated benchmarks, which
//! choose `n = 2^17 … 2^20`). The DCT-II is computed via Makhoul's N-point
//! FFT mapping (no 2n zero-padding): reorder the input as
//! `v_j = x_{2j}`, `v_{n-1-j} = x_{2j+1}`, run one complex FFT, and take
//! `X_k = Re(e^{-iπk/(2n)} V_k)`. The DCT-III is the algebraic transpose of
//! that pipeline (diagonal multiply → FFT → inverse reorder), which is what
//! makes the operator's adjoint property hold to rounding error.

/// Precomputed tables for size-`n` transforms (`n` a power of two).
///
/// Memory: `1.5 n` complex entries (24 bytes/row-equivalent) — at
/// `n = 2^20` about 24 MB, against the 2.4 TB an `m x n` dense matrix
/// would need at the `large_n` bench shape.
#[derive(Clone, Debug)]
pub struct DctPlan {
    n: usize,
    /// FFT twiddles `e^{-2πi j / n}`, `j < n/2`.
    tw_re: Vec<f64>,
    tw_im: Vec<f64>,
    /// DCT phase factors `e^{-iπ k / (2n)}`, `k < n`.
    ph_re: Vec<f64>,
    ph_im: Vec<f64>,
}

/// Reusable complex workspace for one plan (two `n`-length lanes). One per
/// caller (kernels hold their own), so concurrent workers never contend.
#[derive(Clone, Debug, Default)]
pub struct DctScratch {
    re: Vec<f64>,
    im: Vec<f64>,
}

impl DctPlan {
    /// Build tables for size `n`. Panics unless `n` is a power of two.
    pub fn new(n: usize) -> Self {
        assert!(n.is_power_of_two(), "DctPlan: n = {n} must be a power of two");
        let half = n / 2;
        let mut tw_re = Vec::with_capacity(half);
        let mut tw_im = Vec::with_capacity(half);
        for j in 0..half {
            let theta = -2.0 * std::f64::consts::PI * j as f64 / n as f64;
            tw_re.push(theta.cos());
            tw_im.push(theta.sin());
        }
        let mut ph_re = Vec::with_capacity(n);
        let mut ph_im = Vec::with_capacity(n);
        for k in 0..n {
            let theta = -std::f64::consts::PI * k as f64 / (2.0 * n as f64);
            ph_re.push(theta.cos());
            ph_im.push(theta.sin());
        }
        DctPlan { n, tw_re, tw_im, ph_re, ph_im }
    }

    /// Transform size.
    #[inline(always)]
    pub fn n(&self) -> usize {
        self.n
    }

    /// Fresh workspace sized for this plan.
    pub fn scratch(&self) -> DctScratch {
        DctScratch { re: vec![0.0; self.n], im: vec![0.0; self.n] }
    }

    fn check_scratch<'a>(&self, s: &'a mut DctScratch) -> (&'a mut [f64], &'a mut [f64]) {
        s.re.resize(self.n, 0.0);
        s.im.resize(self.n, 0.0);
        (&mut s.re, &mut s.im)
    }

    /// In-place iterative radix-2 FFT with the `e^{-2πi jk/n}` sign
    /// convention (bit-reversal permutation + Cooley–Tukey butterflies).
    fn fft(&self, re: &mut [f64], im: &mut [f64]) {
        let n = self.n;
        debug_assert_eq!(re.len(), n);
        debug_assert_eq!(im.len(), n);
        // Bit-reversal permutation.
        let mut j = 0usize;
        for i in 1..n {
            let mut bit = n >> 1;
            while j & bit != 0 {
                j ^= bit;
                bit >>= 1;
            }
            j |= bit;
            if i < j {
                re.swap(i, j);
                im.swap(i, j);
            }
        }
        // Butterfly passes. Twiddle for stage `len` at offset `k` is
        // e^{-2πi k/len} = tw[k * (n/len)].
        let mut len = 2usize;
        while len <= n {
            let half = len / 2;
            let step = n / len;
            let mut base = 0usize;
            while base < n {
                for k in 0..half {
                    let wr = self.tw_re[k * step];
                    let wi = self.tw_im[k * step];
                    let (ur, ui) = (re[base + k], im[base + k]);
                    let (xr, xi) = (re[base + k + half], im[base + k + half]);
                    let vr = xr * wr - xi * wi;
                    let vi = xr * wi + xi * wr;
                    re[base + k] = ur + vr;
                    im[base + k] = ui + vi;
                    re[base + k + half] = ur - vr;
                    im[base + k + half] = ui - vi;
                }
                base += len;
            }
            len <<= 1;
        }
    }

    /// Unnormalized DCT-II: `out[k] = Σ_j x[j] cos(π k (2j+1) / (2n))`.
    pub fn dct2_into(&self, x: &[f64], scratch: &mut DctScratch, out: &mut [f64]) {
        let n = self.n;
        assert_eq!(x.len(), n, "dct2: input length");
        assert_eq!(out.len(), n, "dct2: output length");
        if n == 1 {
            out[0] = x[0];
            return;
        }
        let (re, im) = self.check_scratch(scratch);
        // Makhoul reorder: v_j = x_{2j}, v_{n-1-j} = x_{2j+1}.
        for j in 0..n / 2 {
            re[j] = x[2 * j];
            re[n - 1 - j] = x[2 * j + 1];
        }
        im.fill(0.0);
        self.fft(re, im);
        // X_k = Re(e^{-iπk/(2n)} V_k).
        for k in 0..n {
            out[k] = self.ph_re[k] * re[k] - self.ph_im[k] * im[k];
        }
    }

    /// Unnormalized DCT-III — the exact transpose of [`DctPlan::dct2_into`]:
    /// `out[j] = Σ_k r[k] cos(π k (2j+1) / (2n))`. Implemented as the
    /// reversed pipeline (phase multiply → FFT → inverse reorder), so
    /// `⟨dct2(x), r⟩ = ⟨x, dct3(r)⟩` holds to rounding error.
    pub fn dct3_into(&self, r: &[f64], scratch: &mut DctScratch, out: &mut [f64]) {
        let n = self.n;
        assert_eq!(r.len(), n, "dct3: input length");
        assert_eq!(out.len(), n, "dct3: output length");
        if n == 1 {
            out[0] = r[0];
            return;
        }
        let (re, im) = self.check_scratch(scratch);
        for k in 0..n {
            re[k] = self.ph_re[k] * r[k];
            im[k] = self.ph_im[k] * r[k];
        }
        self.fft(re, im);
        // Inverse of the Makhoul reorder (the permutation's transpose).
        for j in 0..n / 2 {
            out[2 * j] = re[j];
            out[2 * j + 1] = re[n - 1 - j];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn direct_dct2(x: &[f64]) -> Vec<f64> {
        let n = x.len();
        let nf = n as f64;
        (0..n)
            .map(|k| {
                (0..n)
                    .map(|j| {
                        x[j] * (std::f64::consts::PI * k as f64 * (j as f64 + 0.5) / nf).cos()
                    })
                    .sum()
            })
            .collect()
    }

    fn direct_dct3(r: &[f64]) -> Vec<f64> {
        let n = r.len();
        let nf = n as f64;
        (0..n)
            .map(|j| {
                (0..n)
                    .map(|k| {
                        r[k] * (std::f64::consts::PI * k as f64 * (j as f64 + 0.5) / nf).cos()
                    })
                    .sum()
            })
            .collect()
    }

    fn wave(n: usize, seed: u64) -> Vec<f64> {
        (0..n).map(|i| ((i as f64 + 1.3 * seed as f64) * 0.7129).sin()).collect()
    }

    #[test]
    fn dct2_matches_direct_sum_across_sizes() {
        for n in [1usize, 2, 4, 8, 16, 32, 128, 512] {
            let plan = DctPlan::new(n);
            let mut scratch = plan.scratch();
            let x = wave(n, 1);
            let mut out = vec![0.0; n];
            plan.dct2_into(&x, &mut scratch, &mut out);
            let want = direct_dct2(&x);
            for k in 0..n {
                assert!(
                    (out[k] - want[k]).abs() <= 1e-10 * (1.0 + want[k].abs()),
                    "n={n} k={k}: {} vs {}",
                    out[k],
                    want[k]
                );
            }
        }
    }

    #[test]
    fn dct3_matches_direct_sum_across_sizes() {
        for n in [1usize, 2, 4, 8, 64, 256] {
            let plan = DctPlan::new(n);
            let mut scratch = plan.scratch();
            let r = wave(n, 2);
            let mut out = vec![0.0; n];
            plan.dct3_into(&r, &mut scratch, &mut out);
            let want = direct_dct3(&r);
            for j in 0..n {
                assert!(
                    (out[j] - want[j]).abs() <= 1e-10 * (1.0 + want[j].abs()),
                    "n={n} j={j}: {} vs {}",
                    out[j],
                    want[j]
                );
            }
        }
    }

    #[test]
    fn dct3_is_the_transpose_of_dct2() {
        for n in [2usize, 8, 32, 256] {
            let plan = DctPlan::new(n);
            let mut scratch = plan.scratch();
            let x = wave(n, 3);
            let r = wave(n, 4);
            let mut fx = vec![0.0; n];
            plan.dct2_into(&x, &mut scratch, &mut fx);
            let mut ftr = vec![0.0; n];
            plan.dct3_into(&r, &mut scratch, &mut ftr);
            let lhs: f64 = fx.iter().zip(&r).map(|(&a, &b)| a * b).sum();
            let rhs: f64 = x.iter().zip(&ftr).map(|(&a, &b)| a * b).sum();
            assert!(
                (lhs - rhs).abs() <= 1e-10 * (1.0 + lhs.abs()),
                "n={n}: ⟨Fx,r⟩={lhs} vs ⟨x,Fᵀr⟩={rhs}"
            );
        }
    }

    #[test]
    fn dct2_of_delta_is_a_cosine_row() {
        // x = e_j ⇒ X_k = cos(πk(2j+1)/(2n)) — the j-th column of the
        // DCT-II matrix, which is how the operator's column gather and the
        // transform must agree.
        let n = 16;
        let plan = DctPlan::new(n);
        let mut scratch = plan.scratch();
        for j in [0usize, 1, 7, 15] {
            let mut x = vec![0.0; n];
            x[j] = 1.0;
            let mut out = vec![0.0; n];
            plan.dct2_into(&x, &mut scratch, &mut out);
            for k in 0..n {
                let want =
                    (std::f64::consts::PI * k as f64 * (j as f64 + 0.5) / n as f64).cos();
                assert!((out[k] - want).abs() < 1e-12, "j={j} k={k}");
            }
        }
    }

    #[test]
    fn orthogonality_roundtrip() {
        // DCT-III ∘ DCT-II = diag(n, n/2, ..., n/2) in the unnormalized
        // convention: x^T round-trips up to those known factors.
        let n = 64;
        let plan = DctPlan::new(n);
        let mut scratch = plan.scratch();
        let x = wave(n, 5);
        let mut fx = vec![0.0; n];
        plan.dct2_into(&x, &mut scratch, &mut fx);
        // Scale coefficient k by its inverse weight, transform back.
        let mut scaled = fx.clone();
        scaled[0] /= n as f64;
        for v in scaled.iter_mut().skip(1) {
            *v /= n as f64 / 2.0;
        }
        let mut back = vec![0.0; n];
        plan.dct3_into(&scaled, &mut scratch, &mut back);
        for j in 0..n {
            assert!((back[j] - x[j]).abs() < 1e-10, "j={j}: {} vs {}", back[j], x[j]);
        }
    }

    #[test]
    #[should_panic(expected = "power of two")]
    fn non_power_of_two_rejected() {
        let _ = DctPlan::new(12);
    }

    #[test]
    fn scratch_resizes_on_demand() {
        let plan = DctPlan::new(8);
        let mut scratch = DctScratch::default(); // empty — must self-size
        let x = wave(8, 6);
        let mut out = vec![0.0; 8];
        plan.dct2_into(&x, &mut scratch, &mut out);
        let want = direct_dct2(&x);
        for k in 0..8 {
            assert!((out[k] - want[k]).abs() < 1e-10);
        }
    }
}
