//! CGLS (conjugate gradient on the normal equations) — the iterative
//! least-squares alternative to QR used by StoGradMP when the selected
//! support is large enough that `O(m k^2)` QR becomes noticeable, and as an
//! independent cross-check of the QR solver in tests.

use super::dense::{axpy, dot, Mat};
use super::scalar::Scalar;

/// Outcome of a CGLS solve.
#[derive(Clone, Debug)]
pub struct CglsResult<S: Scalar> {
    /// Solution estimate.
    pub z: Vec<S>,
    /// Iterations executed.
    pub iters: usize,
    /// Final `||A^T (y - A z)||` (normal-equation residual).
    pub grad_norm: S,
    /// Whether `grad_norm <= tol * ||A^T y||` was reached.
    pub converged: bool,
}

/// Solve `min ||A z - y||_2` by CGLS.
///
/// * `tol` — relative tolerance on the normal-equation residual.
/// * `max_iters` — hard cap (the exact solution is reached in `<= k`
///   iterations in exact arithmetic).
pub fn cgls<S: Scalar>(a: &Mat<S>, y: &[S], tol: S, max_iters: usize) -> CglsResult<S> {
    let m = a.rows();
    let k = a.cols();
    assert_eq!(y.len(), m, "rhs length");

    let mut z = vec![S::ZERO; k];
    let mut r = y.to_vec(); // residual y - A z (z = 0)
    let mut s = a.gemv_t(&r); // normal residual A^T r
    let s0_norm = dot(&s, &s).sqrt();
    if s0_norm == S::ZERO {
        return CglsResult { z, iters: 0, grad_norm: S::ZERO, converged: true };
    }
    let threshold = tol * s0_norm;

    let mut p = s.clone();
    let mut gamma = dot(&s, &s);
    let mut q = vec![S::ZERO; m];
    let mut iters = 0;

    for _ in 0..max_iters {
        a.as_block().gemv_into(&p, &mut q);
        let qq = dot(&q, &q);
        if qq == S::ZERO {
            break;
        }
        let alpha = gamma / qq;
        axpy(alpha, &p, &mut z);
        axpy(-alpha, &q, &mut r);
        s = a.gemv_t(&r);
        let gamma_new = dot(&s, &s);
        iters += 1;
        if gamma_new.sqrt() <= threshold {
            return CglsResult { z, iters, grad_norm: gamma_new.sqrt(), converged: true };
        }
        let beta = gamma_new / gamma;
        gamma = gamma_new;
        // p = s + beta p
        for i in 0..k {
            p[i] = s[i] + beta * p[i];
        }
    }
    let grad_norm = dot(&s, &s).sqrt();
    CglsResult { z, iters, grad_norm, converged: grad_norm <= threshold }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::dense::dist2;
    use crate::linalg::qr::lstsq;
    use crate::rng::Rng;

    #[test]
    fn matches_qr_on_random_problems() {
        let mut rng = Rng::seed_from(9);
        for &(m, k) in &[(12usize, 4usize), (50, 12), (80, 30)] {
            let a = Mat::from_fn(m, k, |_, _| rng.gauss());
            let y: Vec<f64> = (0..m).map(|_| rng.gauss()).collect();
            let zq = lstsq(&a, &y);
            let res = cgls(&a, &y, 1e-12, 200);
            assert!(res.converged, "m={m} k={k}");
            assert!(dist2(&res.z, &zq) < 1e-7, "m={m} k={k} dist={}", dist2(&res.z, &zq));
        }
    }

    #[test]
    fn zero_rhs_returns_zero() {
        let a = Mat::<f64>::from_fn(5, 3, |i, j| (i + j) as f64);
        let res = cgls(&a, &[0.0; 5], 1e-10, 50);
        assert!(res.converged);
        assert_eq!(res.iters, 0);
        assert!(res.z.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn exact_in_k_iterations() {
        // Exact arithmetic property holds approximately: k+small iterations.
        let mut rng = Rng::seed_from(11);
        let (m, k) = (40, 6);
        let a = Mat::from_fn(m, k, |_, _| rng.gauss());
        let z_true: Vec<f64> = (0..k).map(|_| rng.gauss()).collect();
        let y = a.gemv(&z_true);
        let res = cgls(&a, &y, 1e-10, 40);
        assert!(res.converged);
        assert!(res.iters <= k + 4, "iters = {}", res.iters);
        assert!(dist2(&res.z, &z_true) < 1e-6);
    }
}
