//! AVX2 kernel bodies (x86_64 only) — the one place in the crate where
//! explicit intrinsics live. Every function here assumes the runtime AVX2
//! probe has passed: the module is private, and the only path in is
//! `super::level()` returning [`super::Level::Avx2`].
//!
//! Parity: each kernel keeps the canonical 4-lane accumulation order (lane
//! `l` of one 256-bit accumulator is exactly the scalar kernel's `s_l`),
//! uses separate `mul`/`add` — never FMA, which would fuse the rounding —
//! and reduces `(s0+s1)+(s2+s3)` with a sequential tail, so results are
//! bit-identical to the `*_scalar` references at every input length.

// The crate denies unsafe_code globally; this module and
// `coordinator::ResultSlots` are the two audited exceptions (see the
// inventory note in src/lib.rs). Every unsafe block below carries a
// SAFETY comment naming the AVX2 precondition — enforced by lint L3/L6
// and clippy::undocumented_unsafe_blocks.
#![allow(unsafe_code)]

use core::arch::x86_64::{
    _mm256_add_pd, _mm256_loadu_pd, _mm256_mul_pd, _mm256_set1_pd, _mm256_setzero_pd,
    _mm256_storeu_pd,
};

/// 256-bit dot product, bit-identical to `super::dot_scalar`.
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    // SAFETY: this module is only reachable through `super::level()`
    // returning `Level::Avx2`, i.e. after the runtime AVX2 probe passed.
    unsafe { dot_avx2(a, b) }
}

/// 256-bit `y += a * x`, bit-identical to `super::axpy_scalar`.
pub fn axpy(a: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    // SAFETY: this module is only reachable through `super::level()`
    // returning `Level::Avx2`, i.e. after the runtime AVX2 probe passed.
    unsafe { axpy_avx2(a, x, y) }
}

/// 256-bit 4-column panel dot (shared row loaded once), each output
/// bit-identical to `super::dot_scalar` on that column.
pub fn dot4(a: &[f64], b: [&[f64]; 4]) -> [f64; 4] {
    for bc in &b {
        debug_assert_eq!(a.len(), bc.len());
    }
    // SAFETY: this module is only reachable through `super::level()`
    // returning `Level::Avx2`, i.e. after the runtime AVX2 probe passed.
    unsafe { dot4_avx2(a, b) }
}

/// SAFETY: callers must have verified AVX2 support at runtime (the
/// `super::level()` probe) — `#[target_feature]` marks this fn unsafe.
#[target_feature(enable = "avx2")]
unsafe fn dot_avx2(a: &[f64], b: &[f64]) -> f64 {
    let n = a.len();
    let chunks = n / 4;
    let mut lanes = [0.0f64; 4];
    // SAFETY (AVX2): probe-verified by the caller; the pointer accesses
    // below are bounds-argued per call site.
    unsafe {
        let (pa, pb) = (a.as_ptr(), b.as_ptr());
        let mut acc = _mm256_setzero_pd();
        for k in 0..chunks {
            let i = 4 * k;
            // SAFETY (AVX2): reads 4 f64 at i = 4k ≤ n − 4, in bounds for
            // both slices; separate mul+add (no FMA) keeps each lane on the
            // scalar kernel's rounding sequence.
            let prod = _mm256_mul_pd(_mm256_loadu_pd(pa.add(i)), _mm256_loadu_pd(pb.add(i)));
            acc = _mm256_add_pd(acc, prod);
        }
        // SAFETY (AVX2): 4-lane store into the 4-element stack array.
        _mm256_storeu_pd(lanes.as_mut_ptr(), acc);
    }
    let mut s = (lanes[0] + lanes[1]) + (lanes[2] + lanes[3]);
    for i in 4 * chunks..n {
        s += a[i] * b[i];
    }
    s
}

/// SAFETY: callers must have verified AVX2 support at runtime (the
/// `super::level()` probe) — `#[target_feature]` marks this fn unsafe.
#[target_feature(enable = "avx2")]
unsafe fn axpy_avx2(a: f64, x: &[f64], y: &mut [f64]) {
    let n = x.len();
    let chunks = n / 4;
    // SAFETY (AVX2): probe-verified by the caller; the pointer accesses
    // below are bounds-argued per call site.
    unsafe {
        let va = _mm256_set1_pd(a);
        let (px, py) = (x.as_ptr(), y.as_mut_ptr());
        for k in 0..chunks {
            let i = 4 * k;
            // SAFETY (AVX2): loads/stores touch 4 f64 at i = 4k ≤ n − 4 —
            // in bounds for `x` and `y` (equal lengths, caller-checked).
            let prod = _mm256_mul_pd(va, _mm256_loadu_pd(px.add(i)));
            _mm256_storeu_pd(py.add(i), _mm256_add_pd(_mm256_loadu_pd(py.add(i)), prod));
        }
    }
    for i in 4 * chunks..n {
        y[i] += a * x[i];
    }
}

/// SAFETY: callers must have verified AVX2 support at runtime (the
/// `super::level()` probe) — `#[target_feature]` marks this fn unsafe.
#[target_feature(enable = "avx2")]
unsafe fn dot4_avx2(a: &[f64], b: [&[f64]; 4]) -> [f64; 4] {
    let n = a.len();
    let chunks = n / 4;
    let mut lanes = [[0.0f64; 4]; 4];
    // SAFETY (AVX2): probe-verified by the caller; the pointer accesses
    // below are bounds-argued per call site.
    unsafe {
        let pa = a.as_ptr();
        let pb = [b[0].as_ptr(), b[1].as_ptr(), b[2].as_ptr(), b[3].as_ptr()];
        let mut acc = [_mm256_setzero_pd(); 4];
        for k in 0..chunks {
            let i = 4 * k;
            // SAFETY (AVX2): reads 4 f64 at i = 4k ≤ n − 4, in bounds for
            // `a` and for every column (equal lengths, caller-checked); the
            // shared row vector is loaded once for all four columns.
            let va = _mm256_loadu_pd(pa.add(i));
            for (ac, p) in acc.iter_mut().zip(pb.iter()) {
                *ac = _mm256_add_pd(*ac, _mm256_mul_pd(va, _mm256_loadu_pd(p.add(i))));
            }
        }
        for (lc, ac) in lanes.iter_mut().zip(acc.iter()) {
            // SAFETY (AVX2): 4-lane store into each 4-element stack row.
            _mm256_storeu_pd(lc.as_mut_ptr(), *ac);
        }
    }
    let mut out = [0.0f64; 4];
    for c in 0..4 {
        let mut s = (lanes[c][0] + lanes[c][1]) + (lanes[c][2] + lanes[c][3]);
        for i in 4 * chunks..n {
            s += a[i] * b[c][i];
        }
        out[c] = s;
    }
    out
}
