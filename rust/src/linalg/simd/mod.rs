//! The crate's single SIMD doorway: explicit-width `f64` kernels behind a
//! one-time runtime feature probe.
//!
//! ## Dispatch model
//!
//! Every entry point ([`dot`], [`axpy`], [`nrm2`], [`dot4`]) consults
//! [`level`], a cached one-time probe that picks the widest supported
//! implementation:
//!
//! * [`Level::Avx2`] — x86_64 whose CPUID reports AVX2: the guarded
//!   intrinsic kernels in the private `avx2` submodule (guaranteed 256-bit
//!   loads regardless of what the autovectorizer felt like doing).
//! * [`Level::Neon`] — aarch64: the canonical loops below, which the
//!   compiler lowers to NEON because the 4-lane shape *is* the 2×`f64x2`
//!   vector shape and NEON is baseline-on for aarch64 (no intrinsics, no
//!   `unsafe`, no runtime check needed), plus the register-blocked panel
//!   kernel [`dot4_blocked`].
//! * [`Level::Scalar`] — everything else, and the forced-override mode: the
//!   canonical reference kernels ([`dot_scalar`] and friends).
//!
//! `ASTIR_SIMD=scalar|neon|avx2|auto` overrides the probe (first call wins;
//! the decision is cached for the process). Requesting a level the host
//! cannot run falls back to `scalar`, so `ASTIR_SIMD=scalar` is a total
//! kill-switch and the only override CI exercises. Unrecognized values mean
//! `auto`.
//!
//! ## Parity contract
//!
//! Dispatch **never changes results**: every level reproduces the canonical
//! 4-lane accumulation order of [`super::dense::dot`] — lane `l` sums the
//! terms at indices `≡ l (mod 4)`, lanes reduce as `(s0+s1)+(s2+s3)`, and
//! the tail past `4·⌊n/4⌋` folds in sequentially — so results are
//! **bit-identical** across `scalar`/`neon`/`avx2` (the AVX2 kernels use
//! separate mul+add, never FMA, precisely to keep each lane's rounding
//! sequence intact). This is deliberately stronger than the crate-wide
//! tolerance contract (≤ 1e-12 relative where a kernel documents
//! reassociation): no kernel in this module reassociates, and
//! `rust/tests/simd_parity.rs` pins the bitwise claim on every entry point.
//! A future level that does reassociate must document it here and downgrade
//! those pins to the toleranced form.
//!
//! ## Doorway rule
//!
//! Lint rule L6 (`simd-doorway`, see [`crate::lint`]) confines
//! `std::arch`/`core::arch` imports, `target_feature` gates, and
//! `_mm*` intrinsics to `src/linalg/simd/`, and requires every intrinsic
//! call site to sit under a `SAFETY:` comment naming the CPU-feature
//! precondition. Outside this directory the crate is plain safe Rust.

use crate::sync::OnceLock;

#[cfg(target_arch = "x86_64")]
mod avx2;

/// Dispatch level selected by the one-time probe (or forced via
/// `ASTIR_SIMD`). Ordering is widest-last so "best available" is the
/// largest supported variant.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Level {
    /// Canonical 4-lane unrolled loops — the reference semantics.
    Scalar,
    /// aarch64 baseline NEON: the canonical loops (autovectorized to
    /// 2×`f64x2`) plus the register-blocked panel kernel.
    Neon,
    /// x86_64 with runtime-verified AVX2: guarded 256-bit intrinsic kernels.
    Avx2,
}

impl Level {
    /// Stable lowercase name (bench labels, logs, `ASTIR_SIMD` values).
    pub fn as_str(self) -> &'static str {
        match self {
            Level::Scalar => "scalar",
            Level::Neon => "neon",
            Level::Avx2 => "avx2",
        }
    }
}

/// The dispatch level every kernel in this module routes through, probed
/// once per process and cached (the probe is a pure function of the CPU and
/// the `ASTIR_SIMD` environment variable, so caching can never go stale).
pub fn level() -> Level {
    static LEVEL: OnceLock<Level> = OnceLock::new();
    *LEVEL.get_or_init(probe)
}

/// Resolve `ASTIR_SIMD` (default `auto`) against what the host supports.
fn probe() -> Level {
    let requested = std::env::var("ASTIR_SIMD").unwrap_or_default();
    match requested.as_str() {
        "scalar" => Level::Scalar,
        "neon" if cfg!(target_arch = "aarch64") => Level::Neon,
        "neon" => Level::Scalar,
        "avx2" if avx2_available() => Level::Avx2,
        "avx2" => Level::Scalar,
        _ => {
            if avx2_available() {
                Level::Avx2
            } else if cfg!(target_arch = "aarch64") {
                Level::Neon
            } else {
                Level::Scalar
            }
        }
    }
}

/// Runtime AVX2 check. Under Miri the std feature probe reports whatever the
/// compile target enabled statically, so interpreted runs are pinned to the
/// portable path outright — Miri only supports a subset of the intrinsics.
#[cfg(target_arch = "x86_64")]
fn avx2_available() -> bool {
    !cfg!(miri) && is_x86_feature_detected!("avx2")
}

#[cfg(not(target_arch = "x86_64"))]
fn avx2_available() -> bool {
    false
}

// ------------------------------------------------------------- dispatched

/// Dispatched dot product. Bit-identical to [`dot_scalar`] at every level
/// (see the module parity contract).
#[inline]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    #[cfg(target_arch = "x86_64")]
    {
        if level() == Level::Avx2 {
            return avx2::dot(a, b);
        }
    }
    dot_scalar(a, b)
}

/// Dispatched `y += a * x`. Elementwise, so bit-identical to [`axpy_scalar`]
/// at every level by construction.
#[inline]
pub fn axpy(a: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    #[cfg(target_arch = "x86_64")]
    {
        if level() == Level::Avx2 {
            avx2::axpy(a, x, y);
            return;
        }
    }
    axpy_scalar(a, x, y);
}

/// Dispatched Euclidean norm: `sqrt(dot(v, v))` through the dispatched dot.
#[inline]
pub fn nrm2(v: &[f64]) -> f64 {
    dot(v, v).sqrt()
}

/// Dispatched 4-column panel dot: `out[c] = ⟨a, b[c]⟩` with the shared row
/// `a` loaded **once** for all four columns — the MMV batch dimension as the
/// SIMD lane. Each column keeps its own canonical 4-lane accumulator, so
/// every output is bit-identical to `dot_scalar(a, b[c])` at every level.
#[inline]
pub fn dot4(a: &[f64], b: [&[f64]; 4]) -> [f64; 4] {
    for bc in &b {
        debug_assert_eq!(a.len(), bc.len());
    }
    #[cfg(target_arch = "x86_64")]
    {
        if level() == Level::Avx2 {
            return avx2::dot4(a, b);
        }
    }
    if level() == Level::Scalar {
        dot4_scalar(a, b)
    } else {
        dot4_blocked(a, b)
    }
}

// -------------------------------------------------------- reference paths

/// Canonical reference dot: the exact 4-lane accumulation order of
/// [`super::dense::dot`], restated here so the dispatched fast paths have a
/// recursion-free baseline to be measured and pinned against.
#[inline]
pub fn dot_scalar(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let chunks = n / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0f64, 0.0f64, 0.0f64, 0.0f64);
    for k in 0..chunks {
        let i = 4 * k;
        s0 += a[i] * b[i];
        s1 += a[i + 1] * b[i + 1];
        s2 += a[i + 2] * b[i + 2];
        s3 += a[i + 3] * b[i + 3];
    }
    let mut s = (s0 + s1) + (s2 + s3);
    for i in 4 * chunks..n {
        s += a[i] * b[i];
    }
    s
}

/// Canonical reference axpy (`y += a * x`), 4-way unrolled like
/// [`super::dense::axpy`].
#[inline]
pub fn axpy_scalar(a: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    let n = x.len();
    let chunks = n / 4;
    for k in 0..chunks {
        let i = 4 * k;
        y[i] += a * x[i];
        y[i + 1] += a * x[i + 1];
        y[i + 2] += a * x[i + 2];
        y[i + 3] += a * x[i + 3];
    }
    for i in 4 * chunks..n {
        y[i] += a * x[i];
    }
}

/// Reference norm on the reference dot.
#[inline]
pub fn nrm2_scalar(v: &[f64]) -> f64 {
    dot_scalar(v, v).sqrt()
}

/// Reference panel dot: four independent [`dot_scalar`] sweeps. This is the
/// *semantic definition* of [`dot4`]; the blocked/AVX2 paths must reproduce
/// it bit-for-bit.
#[inline]
pub fn dot4_scalar(a: &[f64], b: [&[f64]; 4]) -> [f64; 4] {
    [dot_scalar(a, b[0]), dot_scalar(a, b[1]), dot_scalar(a, b[2]), dot_scalar(a, b[3])]
}

/// Row-reuse panel dot in safe Rust: one pass over `a`, interleaving the
/// four columns so `a`'s chunk is register-resident across all of them
/// (4× less traffic on the shared row than [`dot4_scalar`]). Column `c`
/// still owns its private canonical 4-lane accumulator `s[c]`, and the
/// interleaving only reorders *independent* accumulations, so every output
/// is bit-identical to `dot_scalar(a, b[c])`.
#[inline]
pub fn dot4_blocked(a: &[f64], b: [&[f64]; 4]) -> [f64; 4] {
    for bc in &b {
        debug_assert_eq!(a.len(), bc.len());
    }
    let n = a.len();
    let chunks = n / 4;
    let mut s = [[0.0f64; 4]; 4];
    for k in 0..chunks {
        let i = 4 * k;
        for (sc, bc) in s.iter_mut().zip(b.iter()) {
            sc[0] += a[i] * bc[i];
            sc[1] += a[i + 1] * bc[i + 1];
            sc[2] += a[i + 2] * bc[i + 2];
            sc[3] += a[i + 3] * bc[i + 3];
        }
    }
    let mut out = [0.0f64; 4];
    for c in 0..4 {
        let mut t = (s[c][0] + s[c][1]) + (s[c][2] + s[c][3]);
        for i in 4 * chunks..n {
            t += a[i] * b[c][i];
        }
        out[c] = t;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn vecs(n: usize, seed: u64) -> (Vec<f64>, Vec<f64>) {
        let f = |i: usize, s: u64| ((i as f64 + 0.31 * s as f64) * 0.7341).sin() * 1.7;
        ((0..n).map(|i| f(i, seed)).collect(), (0..n).map(|i| f(i, seed + 9)).collect())
    }

    #[test]
    fn level_is_stable_and_named() {
        let l = level();
        assert_eq!(l, level(), "probe must cache");
        assert!(["scalar", "neon", "avx2"].contains(&l.as_str()));
        if std::env::var("ASTIR_SIMD").as_deref() == Ok("scalar") {
            assert_eq!(l, Level::Scalar, "ASTIR_SIMD=scalar must force the reference path");
        }
    }

    #[test]
    fn dispatched_dot_matches_scalar_bitwise() {
        for n in [0usize, 1, 2, 3, 4, 5, 7, 8, 15, 64, 251, 1000] {
            let (a, b) = vecs(n, 1);
            assert_eq!(dot(&a, &b).to_bits(), dot_scalar(&a, &b).to_bits(), "n={n}");
        }
    }

    #[test]
    fn dispatched_axpy_matches_scalar_bitwise() {
        for n in [0usize, 1, 3, 4, 9, 64, 255, 1000] {
            let (x, y0) = vecs(n, 2);
            let mut y_d = y0.clone();
            let mut y_s = y0.clone();
            axpy(0.37, &x, &mut y_d);
            axpy_scalar(0.37, &x, &mut y_s);
            for i in 0..n {
                assert_eq!(y_d[i].to_bits(), y_s[i].to_bits(), "n={n} i={i}");
            }
        }
    }

    #[test]
    fn panel_dot_all_paths_match_reference_bitwise() {
        for n in [0usize, 1, 4, 6, 16, 63, 257, 1000] {
            let (a, b0) = vecs(n, 3);
            let (b1, b2) = vecs(n, 4);
            let (b3, _) = vecs(n, 5);
            let cols = [&b0[..], &b1[..], &b2[..], &b3[..]];
            let want = dot4_scalar(&a, cols);
            for (name, got) in [("dot4", dot4(&a, cols)), ("blocked", dot4_blocked(&a, cols))] {
                for c in 0..4 {
                    assert_eq!(got[c].to_bits(), want[c].to_bits(), "{name} n={n} col {c}");
                }
            }
        }
    }

    #[test]
    fn nrm2_matches_scalar_bitwise() {
        let (v, _) = vecs(333, 6);
        assert_eq!(nrm2(&v).to_bits(), nrm2_scalar(&v).to_bits());
    }

    #[test]
    fn dot_matches_dense_generic_kernel_bitwise() {
        // The dispatch hooks in `dense::dot` rely on this: the module's
        // reference kernel IS the generic kernel's accumulation order.
        let (a, b) = vecs(1003, 7);
        assert_eq!(dot_scalar(&a, &b).to_bits(), crate::linalg::dense::dot(&a, &b).to_bits());
    }
}
