//! Scalar abstraction so the dense substrate works in both `f32` (matching
//! the AOT artifacts) and `f64` (the native solve path; the paper's 1e-7
//! exit tolerance sits below f32 round-off at m = 300).

use std::fmt::Debug;
use std::iter::Sum;
use std::ops::{Add, AddAssign, Div, DivAssign, Mul, MulAssign, Neg, Sub, SubAssign};

/// Floating-point scalar usable by the dense linear-algebra substrate.
pub trait Scalar:
    Copy
    + Clone
    + Debug
    + PartialOrd
    + Default
    + Add<Output = Self>
    + Sub<Output = Self>
    + Mul<Output = Self>
    + Div<Output = Self>
    + Neg<Output = Self>
    + AddAssign
    + SubAssign
    + MulAssign
    + DivAssign
    + Sum
    + Send
    + Sync
    + 'static
{
    const ZERO: Self;
    const ONE: Self;
    /// Machine epsilon for this type.
    const EPS: Self;

    fn from_f64(v: f64) -> Self;
    fn to_f64(self) -> f64;
    fn abs(self) -> Self;
    fn sqrt(self) -> Self;
    fn max_s(self, other: Self) -> Self;
    fn min_s(self, other: Self) -> Self;
    fn is_finite_s(self) -> bool;

    /// Width-dispatch hook for [`super::dense::dot`]: `Some(result)` routes
    /// the call through the [`crate::linalg::simd`] doorway (the `f64`
    /// override — **bit-identical** to the generic 4-lane kernel at every
    /// dispatch level, see that module's parity contract); `None` keeps the
    /// generic loop (`f32`, the PJRT-artifact path).
    #[inline(always)]
    fn simd_dot(_a: &[Self], _b: &[Self]) -> Option<Self> {
        None
    }

    /// Width-dispatch hook for [`super::dense::axpy`]; `true` means the
    /// [`crate::linalg::simd`] doorway handled it (same parity contract as
    /// [`Scalar::simd_dot`]).
    #[inline(always)]
    fn simd_axpy(_a: Self, _x: &[Self], _y: &mut [Self]) -> bool {
        false
    }
}

impl Scalar for f64 {
    const ZERO: Self = 0.0;
    const ONE: Self = 1.0;
    const EPS: Self = f64::EPSILON;

    #[inline(always)]
    fn from_f64(v: f64) -> Self {
        v
    }
    #[inline(always)]
    fn to_f64(self) -> f64 {
        self
    }
    #[inline(always)]
    fn abs(self) -> Self {
        f64::abs(self)
    }
    #[inline(always)]
    fn sqrt(self) -> Self {
        f64::sqrt(self)
    }
    #[inline(always)]
    fn max_s(self, other: Self) -> Self {
        f64::max(self, other)
    }
    #[inline(always)]
    fn min_s(self, other: Self) -> Self {
        f64::min(self, other)
    }
    #[inline(always)]
    fn is_finite_s(self) -> bool {
        f64::is_finite(self)
    }
    #[inline(always)]
    fn simd_dot(a: &[Self], b: &[Self]) -> Option<Self> {
        Some(crate::linalg::simd::dot(a, b))
    }
    #[inline(always)]
    fn simd_axpy(a: Self, x: &[Self], y: &mut [Self]) -> bool {
        crate::linalg::simd::axpy(a, x, y);
        true
    }
}

impl Scalar for f32 {
    const ZERO: Self = 0.0;
    const ONE: Self = 1.0;
    const EPS: Self = f32::EPSILON;

    #[inline(always)]
    fn from_f64(v: f64) -> Self {
        v as f32
    }
    #[inline(always)]
    fn to_f64(self) -> f64 {
        self as f64
    }
    #[inline(always)]
    fn abs(self) -> Self {
        f32::abs(self)
    }
    #[inline(always)]
    fn sqrt(self) -> Self {
        f32::sqrt(self)
    }
    #[inline(always)]
    fn max_s(self, other: Self) -> Self {
        f32::max(self, other)
    }
    #[inline(always)]
    fn min_s(self, other: Self) -> Self {
        f32::min(self, other)
    }
    #[inline(always)]
    fn is_finite_s(self) -> bool {
        f32::is_finite(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip<S: Scalar>(v: f64) -> f64 {
        S::from_f64(v).to_f64()
    }

    #[test]
    fn constants() {
        assert_eq!(f64::ZERO, 0.0);
        assert_eq!(f32::ONE, 1.0);
        assert!(f64::EPS < 1e-15 && f64::EPS > 0.0);
        assert!(f32::EPS < 1e-6 && f32::EPS > 0.0);
    }

    #[test]
    fn conversions() {
        assert_eq!(roundtrip::<f64>(1.25), 1.25);
        assert_eq!(roundtrip::<f32>(1.25), 1.25);
        assert!((roundtrip::<f32>(0.1) - 0.1).abs() < 1e-7);
    }

    #[test]
    fn basic_ops() {
        assert_eq!(f64::from_f64(-3.0).abs(), 3.0);
        assert_eq!(f64::from_f64(9.0).sqrt(), 3.0);
        assert_eq!(2.0f64.max_s(3.0), 3.0);
        assert_eq!(2.0f64.min_s(3.0), 2.0);
        assert!(1.0f32.is_finite_s());
        assert!(!f32::INFINITY.is_finite_s());
    }
}
