//! Matrix-free measurement operators — the `MeasureOp` abstraction the
//! whole solve stack is written against.
//!
//! The paper's cost functions are dense in `x` while the signal is sparse;
//! nothing in StoIHT/StoGradMP actually needs the *matrix*, only the
//! operator actions on one measurement block:
//!
//! * `A_b x` (block apply) and `A_bᵀ r` (block adjoint),
//! * the fused proxy step `x + α A_bᵀ(y_b − A_b x)` (dense + sparse-iterate
//!   forms),
//! * the sparse residual gather `y_b − A_b x` over a known support,
//! * the full-system residual `‖y − A x‖₂` (halting statistic), and
//! * a column gather `A[:, T]` for the GradMP least-squares re-fit.
//!
//! [`MeasureOp`] captures exactly that surface. Two implementations:
//!
//! * [`DenseOp`] — today's materialized `Mat` plus its transposed copy,
//!   delegating to the existing fused kernels **bit-identically** (the
//!   dense path of every algorithm produces the same bits as before this
//!   abstraction existed — pinned by `rust/tests/operator_parity.rs`).
//! * [`SubsampledDctOp`] — the `partial_dct` ensemble without the matrix:
//!   only the `m` sampled row indices and per-row scales are stored, and
//!   every operator action is an O(n log n) fast DCT ([`super::fft`]) or an
//!   O(b·|supp|) direct cosine gather. This is what lets the asynchronous
//!   runtimes run `n = 10^6` recoveries that a dense `m x n` matrix
//!   (2.4 TB at the `large_n` bench shape) could never reach.
//!
//! [`Operator`] is the enum the [`crate::problem::Problem`] stores —
//! match-based (statically dispatched, inlinable) delegation, so the
//! kernels stay generic-free without a vtable on the hot path.
#![allow(clippy::too_many_arguments)]

use super::dense::{axpy, nrm2, Mat};
use super::fft::{plan_for, DctPlan, DctScratch};
use crate::sync::Arc;

/// Caller-owned workspace for [`MeasureOp`] calls. Dense operators need
/// none; the DCT operator needs FFT lanes plus two `n`-length buffers.
/// Kernels hold one per core, so workers never contend or allocate in
/// steady state. Any variant upgrades itself lazily to what the operator
/// at hand requires.
#[derive(Clone, Debug, Default)]
pub enum OpScratch {
    /// No workspace (dense operators).
    #[default]
    None,
    /// Fast-DCT workspace.
    Dct(DctState),
}

/// The [`SubsampledDctOp`] workspace: FFT lanes + scatter/output buffers,
/// plus the support-union / cosine-table scratch the multi-RHS proxy
/// amortizes across a batch (empty until a batched call needs them).
#[derive(Clone, Debug)]
pub struct DctState {
    fft: DctScratch,
    buf_a: Vec<f64>,
    buf_b: Vec<f64>,
    union: Vec<usize>,
    cos_tab: Vec<f64>,
}

impl DctState {
    fn new(plan: &DctPlan) -> Self {
        DctState {
            fft: plan.scratch(),
            buf_a: vec![0.0; plan.n()],
            buf_b: vec![0.0; plan.n()],
            union: Vec::new(),
            cos_tab: Vec::new(),
        }
    }
}

impl OpScratch {
    /// Borrow (lazily creating/resizing) the DCT workspace for `plan`.
    fn dct(&mut self, plan: &DctPlan) -> &mut DctState {
        let stale = match self {
            OpScratch::Dct(st) => st.buf_a.len() != plan.n(),
            OpScratch::None => true,
        };
        if stale {
            *self = OpScratch::Dct(DctState::new(plan));
        }
        match self {
            OpScratch::Dct(st) => st,
            OpScratch::None => unreachable!("just installed"),
        }
    }
}

/// Per-signal views for one **multi-RHS** fused sparse proxy step
/// ([`MeasureOp::block_proxy_step_sparse_multi`]): the batched recovery
/// path steps `B` signals against the same sampled block in lockstep, and
/// each column carries its own measurements, iterate, support, and output
/// buffers. All slices obey the single-signal method's contracts
/// (`x[j] == +0.0` off the strictly ascending `support`).
pub struct ProxyCol<'a> {
    /// This signal's `y` slice for the sampled block (`b` entries).
    pub y_b: &'a [f64],
    /// Dense view of this signal's sparse iterate (`n` entries).
    pub x: &'a [f64],
    /// The iterate's strictly ascending support.
    pub support: &'a [usize],
    /// Residual output `y_b − A_b x` (`b` entries).
    pub resid: &'a mut [f64],
    /// Proxy output `x + alpha · A_bᵀ resid` (`n` entries).
    pub out: &'a mut [f64],
}

/// Operator access to the measurement ensemble `A ∈ R^{m x n}`: everything
/// the recovery algorithms need, with no way to demand a materialized
/// matrix. Implementations must be `Sync` (one operator is shared by all
/// worker threads); all mutable state lives in the caller's [`OpScratch`].
pub trait MeasureOp: Sync {
    /// Number of measurements `m`.
    fn rows(&self) -> usize;

    /// Signal dimension `n`.
    fn cols(&self) -> usize;

    /// Fresh workspace sized for this operator.
    fn make_scratch(&self) -> OpScratch;

    /// The materialized matrices, if this operator has them. Dense-only
    /// consumers (PJRT artifact protocol, the classical baselines'
    /// full-gradient loops) go through this and fail loudly otherwise.
    fn dense(&self) -> Option<&DenseOp> {
        None
    }

    /// `out = A x` (full apply; `out.len() == m`).
    fn apply_into(&self, x: &[f64], scratch: &mut OpScratch, out: &mut [f64]);

    /// `out = Aᵀ r` (full adjoint; `out.len() == n`).
    fn apply_t_into(&self, r: &[f64], scratch: &mut OpScratch, out: &mut [f64]);

    /// `out = A_b x` for the row window `[row0, row0 + out.len())`.
    fn block_apply_into(&self, row0: usize, x: &[f64], scratch: &mut OpScratch, out: &mut [f64]);

    /// `out = beta * out + A_bᵀ r` for the row window `[row0, row0 + r.len())`.
    fn block_apply_t_acc(
        &self,
        row0: usize,
        r: &[f64],
        beta: f64,
        scratch: &mut OpScratch,
        out: &mut [f64],
    );

    /// Fused proxy step `out = x + alpha * A_bᵀ (y_b − A_b x)` on the row
    /// window `[row0, row0 + y_b.len())`; `resid` is the `b`-length
    /// residual scratch.
    fn block_proxy_step(
        &self,
        row0: usize,
        y_b: &[f64],
        x: &[f64],
        alpha: f64,
        resid: &mut [f64],
        scratch: &mut OpScratch,
        out: &mut [f64],
    );

    /// Sparse-iterate twin of [`MeasureOp::block_proxy_step`] under the
    /// [`super::sparse::SparseIterate`] invariant (`x` is `+0.0` off the
    /// strictly ascending `support`). The dense implementation keeps the
    /// existing bit-for-bit contract with the dense kernel.
    fn block_proxy_step_sparse(
        &self,
        row0: usize,
        y_b: &[f64],
        x: &[f64],
        support: &[usize],
        alpha: f64,
        resid: &mut [f64],
        scratch: &mut OpScratch,
        out: &mut [f64],
    );

    /// `resid = y_b − A_b x` touching only the supported columns.
    fn block_residual_sparse(
        &self,
        row0: usize,
        y_b: &[f64],
        x: &[f64],
        support: &[usize],
        resid: &mut [f64],
    );

    /// Multi-RHS apply `OUT = A X` over column-major panels: `x_panel`
    /// holds `B = x_panel.len() / n` signals of length `n` back to back,
    /// `out_panel` the corresponding `B` measurement vectors of length `m`.
    /// Each column is **bit-identical** to [`MeasureOp::apply_into`] on
    /// that signal alone — the batching shares setup (scratch, plan,
    /// streamed matrix panels), never arithmetic. The dense override rides
    /// the [`super::simd::dot4`] panel kernel (batch dim = SIMD lane), the
    /// DCT override shares one plan/workspace borrow per panel.
    fn apply_multi_into(&self, x_panel: &[f64], scratch: &mut OpScratch, out_panel: &mut [f64]) {
        let (n, m) = (self.cols(), self.rows());
        assert!(n > 0 && x_panel.len() % n == 0, "apply_multi: x panel length");
        let ncols = x_panel.len() / n;
        assert_eq!(out_panel.len(), ncols * m, "apply_multi: out panel length");
        for (xc, oc) in x_panel.chunks_exact(n).zip(out_panel.chunks_exact_mut(m)) {
            self.apply_into(xc, scratch, oc);
        }
    }

    /// Multi-RHS twin of [`MeasureOp::block_proxy_step_sparse`]: one fused
    /// proxy step for every column against the same row window, blocking
    /// the apply/adjoint over the multi-vector right-hand side. The default
    /// loops the single-signal kernel; implementations may amortize shared
    /// work (the dense operator streams each `A_b` row once per batch, the
    /// DCT operator evaluates each residual-pass cosine once per batch) but
    /// every column's result must stay **bit-identical** to the
    /// single-signal call — pinned by `rust/tests/service_pool.rs`.
    fn block_proxy_step_sparse_multi(
        &self,
        row0: usize,
        cols: &mut [ProxyCol<'_>],
        alpha: f64,
        scratch: &mut OpScratch,
    ) {
        for c in cols.iter_mut() {
            self.block_proxy_step_sparse(
                row0, c.y_b, c.x, c.support, alpha, c.resid, scratch, c.out,
            );
        }
    }

    /// The halting statistic `‖y − A x‖₂` for a sparse iterate.
    fn residual_norm_sparse(
        &self,
        y: &[f64],
        x: &[f64],
        support: &[usize],
        r_scratch: &mut Vec<f64>,
        scratch: &mut OpScratch,
    ) -> f64;

    /// Row-major `m x cols.len()` gather of the selected columns into a
    /// reused buffer (cleared first) — the GradMP re-fit panel.
    fn select_cols_into(&self, cols: &[usize], out: &mut Vec<f64>);

    /// Allocating convenience apply (problem generation, one-off checks).
    fn apply(&self, x: &[f64]) -> Vec<f64> {
        let mut out = vec![0.0; self.rows()];
        let mut scratch = self.make_scratch();
        self.apply_into(x, &mut scratch, &mut out);
        out
    }
}

// ------------------------------------------------------------------ dense

/// The materialized operator: row-major `A` plus the transposed copy the
/// sparse gathers stream (see README.md, "sparse fast path"). Every method
/// delegates to the existing [`Mat`]/[`super::dense::RowBlock`] kernels, so
/// the dense path is bit-identical to the pre-`MeasureOp` code.
#[derive(Clone, Debug)]
pub struct DenseOp {
    a: Mat<f64>,
    a_t: Mat<f64>,
}

/// Transposed copy of a matrix (row-major `n x m` = column-major `m x n`).
fn transpose(a: &Mat<f64>) -> Mat<f64> {
    Mat::from_fn(a.cols(), a.rows(), |i, j| a.get(j, i))
}

impl DenseOp {
    /// Wrap a matrix, deriving the transposed copy.
    pub fn new(a: Mat<f64>) -> Self {
        let a_t = transpose(&a);
        DenseOp { a, a_t }
    }

    /// The matrix, row-major `m x n`.
    #[inline(always)]
    pub fn a(&self) -> &Mat<f64> {
        &self.a
    }

    /// The transposed copy, row-major `n x m` (row `j` = column `j` of `A`).
    #[inline(always)]
    pub fn a_t(&self) -> &Mat<f64> {
        &self.a_t
    }
}

impl MeasureOp for DenseOp {
    fn rows(&self) -> usize {
        self.a.rows()
    }

    fn cols(&self) -> usize {
        self.a.cols()
    }

    fn make_scratch(&self) -> OpScratch {
        OpScratch::None
    }

    fn dense(&self) -> Option<&DenseOp> {
        Some(self)
    }

    fn apply_into(&self, x: &[f64], _scratch: &mut OpScratch, out: &mut [f64]) {
        self.a.as_block().gemv_into(x, out);
    }

    fn apply_t_into(&self, r: &[f64], _scratch: &mut OpScratch, out: &mut [f64]) {
        self.a.as_block().gemv_t_acc(r, 0.0, out);
    }

    fn apply_multi_into(&self, x_panel: &[f64], scratch: &mut OpScratch, out_panel: &mut [f64]) {
        // Batched GEMV through the 4-column panel dot: each `A` row is
        // streamed once per 4 signals instead of once per signal — a 4x cut
        // in matrix traffic, the whole cost at `m x n` panel shapes. Lane
        // `q` of `simd::dot4` is bit-identical to the single-signal
        // `gemv_into` row dot, so per-column parity with `apply_into` holds
        // (pinned by `apply_multi_matches_per_column_apply`).
        let (n, m) = (self.a.cols(), self.a.rows());
        assert!(n > 0 && x_panel.len() % n == 0, "apply_multi: x panel length");
        let ncols = x_panel.len() / n;
        assert_eq!(out_panel.len(), ncols * m, "apply_multi: out panel length");
        let blk = self.a.as_block();
        let mut c = 0usize;
        while c + 4 <= ncols {
            let xs = [
                &x_panel[c * n..(c + 1) * n],
                &x_panel[(c + 1) * n..(c + 2) * n],
                &x_panel[(c + 2) * n..(c + 3) * n],
                &x_panel[(c + 3) * n..(c + 4) * n],
            ];
            for i in 0..m {
                let d = super::simd::dot4(blk.row(i), xs);
                for (q, dq) in d.into_iter().enumerate() {
                    out_panel[(c + q) * m + i] = dq;
                }
            }
            c += 4;
        }
        // Remainder columns (< 4) take the single-signal path.
        for (xc, oc) in x_panel.chunks_exact(n).zip(out_panel.chunks_exact_mut(m)).skip(c) {
            self.apply_into(xc, scratch, oc);
        }
    }

    fn block_apply_into(&self, row0: usize, x: &[f64], _scratch: &mut OpScratch, out: &mut [f64]) {
        self.a.row_block(row0, row0 + out.len()).gemv_into(x, out);
    }

    fn block_apply_t_acc(
        &self,
        row0: usize,
        r: &[f64],
        beta: f64,
        _scratch: &mut OpScratch,
        out: &mut [f64],
    ) {
        self.a.row_block(row0, row0 + r.len()).gemv_t_acc(r, beta, out);
    }

    fn block_proxy_step(
        &self,
        row0: usize,
        y_b: &[f64],
        x: &[f64],
        alpha: f64,
        resid: &mut [f64],
        _scratch: &mut OpScratch,
        out: &mut [f64],
    ) {
        self.a.row_block(row0, row0 + y_b.len()).proxy_step_into(y_b, x, alpha, resid, out);
    }

    fn block_proxy_step_sparse(
        &self,
        row0: usize,
        y_b: &[f64],
        x: &[f64],
        support: &[usize],
        alpha: f64,
        resid: &mut [f64],
        _scratch: &mut OpScratch,
        out: &mut [f64],
    ) {
        self.a
            .row_block(row0, row0 + y_b.len())
            .proxy_step_sparse_into(&self.a_t, row0, y_b, x, support, alpha, resid, out);
    }

    fn block_residual_sparse(
        &self,
        row0: usize,
        y_b: &[f64],
        x: &[f64],
        support: &[usize],
        resid: &mut [f64],
    ) {
        self.a
            .row_block(row0, row0 + y_b.len())
            .residual_sparse_into(&self.a_t, row0, y_b, x, support, resid);
    }

    fn block_proxy_step_sparse_multi(
        &self,
        row0: usize,
        cols: &mut [ProxyCol<'_>],
        alpha: f64,
        _scratch: &mut OpScratch,
    ) {
        let Some(first) = cols.first() else { return };
        let b = first.y_b.len();
        let n = self.a.cols();
        let blk = self.a.row_block(row0, row0 + b);
        // pass 1 per column: the sparse residual gather is O(b·|supp|) and
        // column-specific — batching it would share nothing.
        for c in cols.iter_mut() {
            assert_eq!(c.y_b.len(), b, "proxy_multi: ragged block");
            assert_eq!(c.out.len(), n, "proxy_multi: out length");
            blk.residual_sparse_into(&self.a_t, row0, c.y_b, c.x, c.support, c.resid);
            c.out.fill(0.0);
            for &j in c.support {
                c.out[j] = c.x[j];
            }
        }
        // pass 2 fused: `out_c += alpha·resid_c[i] · A_b[i, chunk]` with the
        // row chunk loaded ONCE per batch instead of once per signal — the
        // B-fold matrix-traffic reduction that makes the dense batched path
        // beat B sequential proxies. Per column the (chunk asc, row asc)
        // axpy sequence is exactly `proxy_step_sparse_into`'s, so each
        // column's bits are unchanged.
        const CHUNK: usize = 1024;
        let mut c0 = 0usize;
        while c0 < n {
            let c1 = (c0 + CHUNK).min(n);
            for i in 0..b {
                let row = &blk.row(i)[c0..c1];
                for c in cols.iter_mut() {
                    let w = alpha * c.resid[i];
                    if w == 0.0 {
                        continue;
                    }
                    axpy(w, row, &mut c.out[c0..c1]);
                }
            }
            c0 = c1;
        }
    }

    fn residual_norm_sparse(
        &self,
        y: &[f64],
        x: &[f64],
        support: &[usize],
        r_scratch: &mut Vec<f64>,
        _scratch: &mut OpScratch,
    ) -> f64 {
        debug_assert!(support.windows(2).all(|w| w[0] < w[1]));
        let m = self.a.rows();
        r_scratch.clear();
        r_scratch.extend_from_slice(y);
        for &j in support {
            let xj = x[j];
            if xj != 0.0 {
                axpy(-xj, &self.a_t.row(j)[..m], r_scratch);
            }
        }
        nrm2(r_scratch)
    }

    fn select_cols_into(&self, cols: &[usize], out: &mut Vec<f64>) {
        self.a.select_cols_into(cols, out);
    }
}

// ---------------------------------------------------------- subsampled DCT

/// Matrix-free subsampled-DCT measurement operator: `m` distinct rows of
/// the `n x n` orthonormal DCT-II matrix scaled by `√(n/m)` — exactly the
/// `partial_dct` ensemble, with only the row indices stored. Entry
/// `(i, j)` is `row_scale[i] · cos(π k_i (j + ½) / n)`, evaluated
/// identically (bit-for-bit) to the dense generator's formula, so the two
/// representations of one drawn ensemble agree entrywise.
///
/// Costs: block apply/adjoint and the proxy steps are one fast transform
/// each — O(n log n) independent of the block size; sparse residual
/// gathers are O(b·|supp|) direct cosines; the re-fit column gather is
/// O(m) cosines per column. `n` must be a power of two (the FFT plan's
/// requirement); plans come from the process-wide [`plan_for`] cache, so
/// rebuilding operators of one size (serve traffic, pool rebuilds) shares
/// one table build instead of redoing O(n) trig each time.
#[derive(Clone, Debug)]
pub struct SubsampledDctOp {
    n: usize,
    /// Sampled DCT row indices `k_i` (distinct, in sampling order — row `i`
    /// of this operator is row `i` of the dense ensemble drawn from the
    /// same RNG stream).
    rows: Vec<usize>,
    /// `√(n/m) · c0(k_i)` per row (the orthonormalization × unit-column
    /// scaling the dense ensemble bakes into every entry).
    row_scale: Vec<f64>,
    /// Shared transform plan from the [`plan_for`] cache (immutable; clones
    /// of this operator share one table set).
    plan: Arc<DctPlan>,
}

impl SubsampledDctOp {
    /// Build from the sampled row indices (distinct, `< n`); `n` must be a
    /// power of two.
    pub fn new(n: usize, rows: Vec<usize>) -> Self {
        assert!(n.is_power_of_two(), "SubsampledDctOp: n = {n} must be a power of two");
        let m = rows.len();
        assert!(m > 0 && m <= n, "SubsampledDctOp: need 0 < m <= n, got m = {m}");
        let nf = n as f64;
        let sc = (n as f64 / m as f64).sqrt();
        // Distinctness is load-bearing, not just conventional: the adjoint
        // scatters assign (not accumulate) into coordinate `k_i`, so a
        // duplicate row would silently drop a contribution and break
        // ⟨A x, r⟩ = ⟨x, Aᵀ r⟩.
        let mut seen = vec![false; n];
        let row_scale = rows
            .iter()
            .map(|&k| {
                assert!(k < n, "SubsampledDctOp: row index {k} out of range");
                assert!(!seen[k], "SubsampledDctOp: duplicate row index {k}");
                seen[k] = true;
                let c0 = if k == 0 { (1.0 / nf).sqrt() } else { (2.0 / nf).sqrt() };
                sc * c0
            })
            .collect();
        SubsampledDctOp { n, rows, row_scale, plan: plan_for(n) }
    }

    /// The sampled DCT row indices.
    pub fn row_indices(&self) -> &[usize] {
        &self.rows
    }

    /// Entry `(i, j)` — the same floating-point expression the dense
    /// `partial_dct` generator evaluates, so dense and matrix-free draws of
    /// one ensemble are entrywise bit-identical.
    #[inline]
    pub fn entry(&self, i: usize, j: usize) -> f64 {
        let nf = self.n as f64;
        let k = self.rows[i] as f64;
        self.row_scale[i] * (std::f64::consts::PI * k * (j as f64 + 0.5) / nf).cos()
    }
}

impl MeasureOp for SubsampledDctOp {
    fn rows(&self) -> usize {
        self.rows.len()
    }

    fn cols(&self) -> usize {
        self.n
    }

    fn make_scratch(&self) -> OpScratch {
        OpScratch::Dct(DctState::new(&self.plan))
    }

    fn apply_into(&self, x: &[f64], scratch: &mut OpScratch, out: &mut [f64]) {
        assert_eq!(x.len(), self.n, "apply: x length");
        assert_eq!(out.len(), self.rows.len(), "apply: out length");
        let DctState { fft, buf_a, .. } = scratch.dct(&self.plan);
        self.plan.dct2_into(x, fft, buf_a);
        for (i, o) in out.iter_mut().enumerate() {
            *o = self.row_scale[i] * buf_a[self.rows[i]];
        }
    }

    fn apply_t_into(&self, r: &[f64], scratch: &mut OpScratch, out: &mut [f64]) {
        assert_eq!(r.len(), self.rows.len(), "apply_t: r length");
        assert_eq!(out.len(), self.n, "apply_t: out length");
        let DctState { fft, buf_a, .. } = scratch.dct(&self.plan);
        buf_a.fill(0.0);
        for (i, &ri) in r.iter().enumerate() {
            buf_a[self.rows[i]] = self.row_scale[i] * ri;
        }
        self.plan.dct3_into(buf_a, fft, out);
    }

    fn apply_multi_into(&self, x_panel: &[f64], scratch: &mut OpScratch, out_panel: &mut [f64]) {
        // The batched DCT apply: one plan + one workspace borrow for the
        // whole panel, a fresh forward transform per column (transforms are
        // column-local, so the per-column bits equal `apply_into`'s).
        let n = self.n;
        let m = self.rows.len();
        assert!(x_panel.len() % n == 0, "apply_multi: x panel length");
        let ncols = x_panel.len() / n;
        assert_eq!(out_panel.len(), ncols * m, "apply_multi: out panel length");
        let DctState { fft, buf_a, .. } = scratch.dct(&self.plan);
        for (xc, oc) in x_panel.chunks_exact(n).zip(out_panel.chunks_exact_mut(m)) {
            self.plan.dct2_into(xc, fft, buf_a);
            for (i, o) in oc.iter_mut().enumerate() {
                *o = self.row_scale[i] * buf_a[self.rows[i]];
            }
        }
    }

    fn block_apply_into(&self, row0: usize, x: &[f64], scratch: &mut OpScratch, out: &mut [f64]) {
        assert!(row0 + out.len() <= self.rows.len(), "block_apply: row window");
        let DctState { fft, buf_a, .. } = scratch.dct(&self.plan);
        self.plan.dct2_into(x, fft, buf_a);
        for (i, o) in out.iter_mut().enumerate() {
            let g = row0 + i;
            *o = self.row_scale[g] * buf_a[self.rows[g]];
        }
    }

    fn block_apply_t_acc(
        &self,
        row0: usize,
        r: &[f64],
        beta: f64,
        scratch: &mut OpScratch,
        out: &mut [f64],
    ) {
        assert!(row0 + r.len() <= self.rows.len(), "block_apply_t: row window");
        assert_eq!(out.len(), self.n, "block_apply_t: out length");
        let DctState { fft, buf_a, buf_b, .. } = scratch.dct(&self.plan);
        buf_a.fill(0.0);
        for (i, &ri) in r.iter().enumerate() {
            let g = row0 + i;
            buf_a[self.rows[g]] = self.row_scale[g] * ri;
        }
        self.plan.dct3_into(buf_a, fft, buf_b);
        if beta == 0.0 {
            out.copy_from_slice(buf_b);
        } else {
            if beta != 1.0 {
                for o in out.iter_mut() {
                    *o *= beta;
                }
            }
            for (o, &d) in out.iter_mut().zip(buf_b.iter()) {
                *o += d;
            }
        }
    }

    fn block_proxy_step(
        &self,
        row0: usize,
        y_b: &[f64],
        x: &[f64],
        alpha: f64,
        resid: &mut [f64],
        scratch: &mut OpScratch,
        out: &mut [f64],
    ) {
        let b = y_b.len();
        assert_eq!(resid.len(), b, "proxy: resid length");
        assert_eq!(out.len(), self.n, "proxy: out length");
        let DctState { fft, buf_a, buf_b, .. } = scratch.dct(&self.plan);
        // pass 1: resid = y_b − A_b x (one forward transform + gather).
        self.plan.dct2_into(x, fft, buf_a);
        for i in 0..b {
            let g = row0 + i;
            resid[i] = y_b[i] - self.row_scale[g] * buf_a[self.rows[g]];
        }
        // pass 2: out = x + alpha · A_bᵀ resid (scatter + one transpose
        // transform).
        buf_a.fill(0.0);
        for i in 0..b {
            let g = row0 + i;
            buf_a[self.rows[g]] = self.row_scale[g] * resid[i];
        }
        self.plan.dct3_into(buf_a, fft, buf_b);
        for j in 0..self.n {
            out[j] = x[j] + alpha * buf_b[j];
        }
    }

    fn block_proxy_step_sparse(
        &self,
        row0: usize,
        y_b: &[f64],
        x: &[f64],
        support: &[usize],
        alpha: f64,
        resid: &mut [f64],
        scratch: &mut OpScratch,
        out: &mut [f64],
    ) {
        let b = y_b.len();
        assert_eq!(out.len(), self.n, "proxy_sparse: out length");
        // pass 1: direct cosine gather over the supported columns —
        // O(b·|supp|), no transform.
        self.block_residual_sparse(row0, y_b, x, support, resid);
        // pass 2: out = x + alpha · A_bᵀ resid; x is zero off `support`, so
        // the sparse scatter replaces the dense add.
        let DctState { fft, buf_a, buf_b, .. } = scratch.dct(&self.plan);
        buf_a.fill(0.0);
        for i in 0..b {
            let g = row0 + i;
            buf_a[self.rows[g]] = self.row_scale[g] * resid[i];
        }
        self.plan.dct3_into(buf_a, fft, buf_b);
        for j in 0..self.n {
            out[j] = alpha * buf_b[j];
        }
        for &j in support {
            out[j] += x[j];
        }
    }

    fn block_proxy_step_sparse_multi(
        &self,
        row0: usize,
        cols: &mut [ProxyCol<'_>],
        alpha: f64,
        scratch: &mut OpScratch,
    ) {
        let Some(first) = cols.first() else { return };
        let b = first.y_b.len();
        assert!(row0 + b <= self.rows.len(), "proxy_multi: row window");
        let nf = self.n as f64;
        let DctState { fft, buf_a, buf_b, union, cos_tab } = scratch.dct(&self.plan);
        // Support union across the batch (ascending): each residual-pass
        // cosine is a pure function of (row, column), so it is evaluated
        // once per batch here instead of once per signal.
        union.clear();
        for c in cols.iter() {
            assert_eq!(c.y_b.len(), b, "proxy_multi: ragged block");
            assert_eq!(c.resid.len(), b, "proxy_multi: resid length");
            assert_eq!(c.out.len(), self.n, "proxy_multi: out length");
            union.extend_from_slice(c.support);
        }
        union.sort_unstable();
        union.dedup();
        let u = union.len();
        cos_tab.clear();
        cos_tab.reserve(b * u);
        for i in 0..b {
            let k = self.rows[row0 + i] as f64;
            for &j in union.iter() {
                // The exact expression `block_residual_sparse` evaluates.
                cos_tab.push((std::f64::consts::PI * k * (j as f64 + 0.5) / nf).cos());
            }
        }
        for c in cols.iter_mut() {
            // pass 1: the direct cosine gather through the shared table —
            // per column the accumulation walks its own support ascending
            // with the identical multiply, so the bits match the
            // single-signal gather.
            for i in 0..b {
                let g = row0 + i;
                let row_tab = &cos_tab[i * u..(i + 1) * u];
                let mut s = 0.0;
                let mut ui = 0usize;
                for &j in c.support {
                    while union[ui] < j {
                        ui += 1;
                    }
                    s += row_tab[ui] * c.x[j];
                }
                c.resid[i] = c.y_b[i] - self.row_scale[g] * s;
            }
            // pass 2: scatter + one DCT-III per column, verbatim from
            // `block_proxy_step_sparse` (the transform is column-local —
            // nothing to amortize but the workspace borrow).
            buf_a.fill(0.0);
            for i in 0..b {
                let g = row0 + i;
                buf_a[self.rows[g]] = self.row_scale[g] * c.resid[i];
            }
            self.plan.dct3_into(buf_a, fft, buf_b);
            for j in 0..self.n {
                c.out[j] = alpha * buf_b[j];
            }
            for &j in c.support {
                c.out[j] += c.x[j];
            }
        }
    }

    fn block_residual_sparse(
        &self,
        row0: usize,
        y_b: &[f64],
        x: &[f64],
        support: &[usize],
        resid: &mut [f64],
    ) {
        let b = y_b.len();
        assert!(row0 + b <= self.rows.len(), "residual_sparse: row window");
        assert_eq!(resid.len(), b, "residual_sparse: resid length");
        debug_assert!(support.windows(2).all(|w| w[0] < w[1]));
        let nf = self.n as f64;
        for i in 0..b {
            let g = row0 + i;
            let k = self.rows[g] as f64;
            let mut s = 0.0;
            for &j in support {
                s += (std::f64::consts::PI * k * (j as f64 + 0.5) / nf).cos() * x[j];
            }
            resid[i] = y_b[i] - self.row_scale[g] * s;
        }
    }

    fn residual_norm_sparse(
        &self,
        y: &[f64],
        x: &[f64],
        support: &[usize],
        r_scratch: &mut Vec<f64>,
        scratch: &mut OpScratch,
    ) -> f64 {
        // One forward transform beats O(m·|supp|) cosine gathers for any
        // support once m is large; `support` only certifies the invariant.
        debug_assert!(support.windows(2).all(|w| w[0] < w[1]));
        let m = self.rows.len();
        assert_eq!(y.len(), m, "residual_norm_sparse: y length");
        let DctState { fft, buf_a, .. } = scratch.dct(&self.plan);
        self.plan.dct2_into(x, fft, buf_a);
        r_scratch.clear();
        r_scratch.extend_from_slice(y);
        for i in 0..m {
            r_scratch[i] -= self.row_scale[i] * buf_a[self.rows[i]];
        }
        nrm2(r_scratch)
    }

    fn select_cols_into(&self, cols: &[usize], out: &mut Vec<f64>) {
        // Row-major m x cols.len(), matching Mat::select_cols_into's layout.
        let m = self.rows.len();
        out.clear();
        out.reserve(m * cols.len());
        for i in 0..m {
            for &j in cols {
                out.push(self.entry(i, j));
            }
        }
    }
}

// --------------------------------------------------------------- operator

/// The measurement operator a [`crate::problem::Problem`] stores: concrete
/// enum storage (so `Problem` stays `Clone` and non-generic) delegating
/// every [`MeasureOp`] method to the wrapped implementation by match —
/// static dispatch, so the dense fused kernels inline into the callers.
#[derive(Clone, Debug)]
pub enum Operator {
    /// Materialized matrix + transposed copy (`dense_a = true`, default).
    Dense(DenseOp),
    /// Matrix-free subsampled DCT (`partial_dct` with `dense_a = false`).
    SubsampledDct(SubsampledDctOp),
}

/// Statically-dispatched delegation: each forwarding method matches on the
/// variant so the dense fused kernels stay inlinable into the per-iteration
/// hot path (a `&dyn` shim would put a vtable call between
/// `StoihtKernel::step_sparse` and `proxy_step_sparse_into`).
macro_rules! dispatch {
    ($self:ident, $op:ident => $call:expr) => {
        match $self {
            Operator::Dense($op) => $call,
            Operator::SubsampledDct($op) => $call,
        }
    };
}

impl Operator {
    /// Short name for diagnostics.
    pub fn name(&self) -> &'static str {
        match self {
            Operator::Dense(_) => "dense",
            Operator::SubsampledDct(_) => "subsampled_dct",
        }
    }
}

impl MeasureOp for Operator {
    fn rows(&self) -> usize {
        dispatch!(self, op => op.rows())
    }

    fn cols(&self) -> usize {
        dispatch!(self, op => op.cols())
    }

    fn make_scratch(&self) -> OpScratch {
        dispatch!(self, op => op.make_scratch())
    }

    fn dense(&self) -> Option<&DenseOp> {
        dispatch!(self, op => op.dense())
    }

    fn apply_into(&self, x: &[f64], scratch: &mut OpScratch, out: &mut [f64]) {
        dispatch!(self, op => op.apply_into(x, scratch, out))
    }

    fn apply_t_into(&self, r: &[f64], scratch: &mut OpScratch, out: &mut [f64]) {
        dispatch!(self, op => op.apply_t_into(r, scratch, out))
    }

    fn apply_multi_into(&self, x_panel: &[f64], scratch: &mut OpScratch, out_panel: &mut [f64]) {
        dispatch!(self, op => op.apply_multi_into(x_panel, scratch, out_panel))
    }

    fn block_apply_into(&self, row0: usize, x: &[f64], scratch: &mut OpScratch, out: &mut [f64]) {
        dispatch!(self, op => op.block_apply_into(row0, x, scratch, out))
    }

    fn block_apply_t_acc(
        &self,
        row0: usize,
        r: &[f64],
        beta: f64,
        scratch: &mut OpScratch,
        out: &mut [f64],
    ) {
        dispatch!(self, op => op.block_apply_t_acc(row0, r, beta, scratch, out))
    }

    fn block_proxy_step(
        &self,
        row0: usize,
        y_b: &[f64],
        x: &[f64],
        alpha: f64,
        resid: &mut [f64],
        scratch: &mut OpScratch,
        out: &mut [f64],
    ) {
        dispatch!(self, op => op.block_proxy_step(row0, y_b, x, alpha, resid, scratch, out))
    }

    fn block_proxy_step_sparse(
        &self,
        row0: usize,
        y_b: &[f64],
        x: &[f64],
        support: &[usize],
        alpha: f64,
        resid: &mut [f64],
        scratch: &mut OpScratch,
        out: &mut [f64],
    ) {
        dispatch!(
            self,
            op => op.block_proxy_step_sparse(row0, y_b, x, support, alpha, resid, scratch, out)
        )
    }

    fn block_proxy_step_sparse_multi(
        &self,
        row0: usize,
        cols: &mut [ProxyCol<'_>],
        alpha: f64,
        scratch: &mut OpScratch,
    ) {
        dispatch!(self, op => op.block_proxy_step_sparse_multi(row0, cols, alpha, scratch))
    }

    fn block_residual_sparse(
        &self,
        row0: usize,
        y_b: &[f64],
        x: &[f64],
        support: &[usize],
        resid: &mut [f64],
    ) {
        dispatch!(self, op => op.block_residual_sparse(row0, y_b, x, support, resid))
    }

    fn residual_norm_sparse(
        &self,
        y: &[f64],
        x: &[f64],
        support: &[usize],
        r_scratch: &mut Vec<f64>,
        scratch: &mut OpScratch,
    ) -> f64 {
        dispatch!(self, op => op.residual_norm_sparse(y, x, support, r_scratch, scratch))
    }

    fn select_cols_into(&self, cols: &[usize], out: &mut Vec<f64>) {
        dispatch!(self, op => op.select_cols_into(cols, out))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::dot;
    use crate::rng::Rng;

    /// The dense twin of a [`SubsampledDctOp`]: the same ensemble
    /// materialized through the same entry formula.
    fn densify(op: &SubsampledDctOp) -> DenseOp {
        DenseOp::new(Mat::from_fn(op.rows(), op.cols(), |i, j| op.entry(i, j)))
    }

    fn dct_op(n: usize, m: usize, seed: u64) -> SubsampledDctOp {
        let mut rng = Rng::seed_from(seed);
        SubsampledDctOp::new(n, rng.subset(n, m))
    }

    fn wave(n: usize, k: u64) -> Vec<f64> {
        (0..n).map(|i| ((i as f64 + k as f64) * 0.613).sin()).collect()
    }

    #[test]
    fn dct_entries_match_the_dense_ensemble_formula() {
        // The exact expression the dense partial_dct generator evaluates.
        let (n, m) = (32usize, 16usize);
        let op = dct_op(n, m, 1);
        let nf = n as f64;
        let sc = (n as f64 / m as f64).sqrt();
        for (i, &k) in op.row_indices().iter().enumerate() {
            for j in 0..n {
                let c0 = if k == 0 { (1.0 / nf).sqrt() } else { (2.0 / nf).sqrt() };
                let want =
                    sc * c0 * (std::f64::consts::PI * k as f64 * (j as f64 + 0.5) / nf).cos();
                assert_eq!(op.entry(i, j).to_bits(), want.to_bits(), "entry ({i}, {j})");
            }
        }
    }

    #[test]
    fn dct_apply_matches_dense_apply() {
        for (n, m) in [(16usize, 8usize), (64, 32), (128, 48)] {
            let op = dct_op(n, m, 2);
            let dense = densify(&op);
            let x = wave(n, 0);
            let mut scratch = op.make_scratch();
            let mut got = vec![0.0; m];
            op.apply_into(&x, &mut scratch, &mut got);
            let mut none = OpScratch::None;
            let mut want = vec![0.0; m];
            dense.apply_into(&x, &mut none, &mut want);
            for i in 0..m {
                assert!(
                    (got[i] - want[i]).abs() <= 1e-12 * (1.0 + want[i].abs()),
                    "n={n} row {i}: {} vs {}",
                    got[i],
                    want[i]
                );
            }
        }
    }

    #[test]
    fn dct_adjoint_matches_dense_adjoint() {
        let (n, m) = (64usize, 24usize);
        let op = dct_op(n, m, 3);
        let dense = densify(&op);
        let r = wave(m, 1);
        let mut scratch = op.make_scratch();
        let mut got = vec![0.0; n];
        op.apply_t_into(&r, &mut scratch, &mut got);
        let mut none = OpScratch::None;
        let mut want = vec![0.0; n];
        dense.apply_t_into(&r, &mut none, &mut want);
        for j in 0..n {
            assert!(
                (got[j] - want[j]).abs() <= 1e-12 * (1.0 + want[j].abs()),
                "coord {j}: {} vs {}",
                got[j],
                want[j]
            );
        }
    }

    #[test]
    fn dct_block_ops_match_dense_block_ops() {
        let (n, m, b) = (64usize, 32usize, 8usize);
        let op = dct_op(n, m, 4);
        let dense = densify(&op);
        let x = wave(n, 2);
        let r = wave(b, 3);
        let mut sd = op.make_scratch();
        let mut none = OpScratch::None;
        for block in 0..m / b {
            let row0 = block * b;
            let mut got_b = vec![0.0; b];
            op.block_apply_into(row0, &x, &mut sd, &mut got_b);
            let mut want_b = vec![0.0; b];
            dense.block_apply_into(row0, &x, &mut none, &mut want_b);
            for i in 0..b {
                assert!((got_b[i] - want_b[i]).abs() < 1e-12, "block {block} apply row {i}");
            }
            for beta in [0.0, 1.0, 0.5] {
                let mut got_t = wave(n, 9);
                op.block_apply_t_acc(row0, &r, beta, &mut sd, &mut got_t);
                let mut want_t = wave(n, 9);
                dense.block_apply_t_acc(row0, &r, beta, &mut none, &mut want_t);
                for j in 0..n {
                    assert!(
                        (got_t[j] - want_t[j]).abs() <= 1e-12 * (1.0 + want_t[j].abs()),
                        "block {block} beta {beta} adjoint coord {j}"
                    );
                }
            }
        }
    }

    #[test]
    fn dct_proxy_steps_match_dense_proxy_steps() {
        let (n, m, b) = (64usize, 16usize, 4usize);
        let op = dct_op(n, m, 5);
        let dense = densify(&op);
        let y = wave(m, 4);
        // A sparse x (zero off support) exercises both proxy forms.
        let support = vec![3usize, 17, 40, 41];
        let mut x = vec![0.0; n];
        for (q, &j) in support.iter().enumerate() {
            x[j] = 0.3 + q as f64 * 0.2;
        }
        let mut sd = op.make_scratch();
        let mut none = OpScratch::None;
        for block in 0..m / b {
            let row0 = block * b;
            let yb = &y[row0..row0 + b];
            let (mut rd, mut rs) = (vec![0.0; b], vec![0.0; b]);
            let (mut got, mut want) = (vec![0.0; n], vec![0.0; n]);
            op.block_proxy_step(row0, yb, &x, 0.8, &mut rd, &mut sd, &mut got);
            dense.block_proxy_step(row0, yb, &x, 0.8, &mut rs, &mut none, &mut want);
            for j in 0..n {
                assert!(
                    (got[j] - want[j]).abs() <= 1e-12 * (1.0 + want[j].abs()),
                    "block {block} dense-form coord {j}"
                );
            }
            op.block_proxy_step_sparse(row0, yb, &x, &support, 0.8, &mut rd, &mut sd, &mut got);
            let (sp, al) = (&support[..], 0.8);
            dense.block_proxy_step_sparse(row0, yb, &x, sp, al, &mut rs, &mut none, &mut want);
            for j in 0..n {
                assert!(
                    (got[j] - want[j]).abs() <= 1e-12 * (1.0 + want[j].abs()),
                    "block {block} sparse-form coord {j}"
                );
            }
            // The two forms of the same operator agree with each other too.
            let mut got_dense_form = vec![0.0; n];
            op.block_proxy_step(row0, yb, &x, 0.8, &mut rd, &mut sd, &mut got_dense_form);
            for j in 0..n {
                assert!((got[j] - got_dense_form[j]).abs() < 1e-12, "form mismatch coord {j}");
            }
        }
    }

    /// Batched-vs-single bitwise parity for the multi-RHS fused proxy on
    /// one operator: every column of `block_proxy_step_sparse_multi` must
    /// reproduce `block_proxy_step_sparse` exactly (overlapping, disjoint,
    /// and empty supports included).
    fn check_proxy_multi_matches_single(op: &Operator, n: usize, b: usize, seed: u64) {
        let mut rng = Rng::seed_from(seed);
        let supports: Vec<Vec<usize>> = vec![
            {
                let mut s = rng.subset(n, 5);
                s.sort_unstable();
                s
            },
            {
                let mut s = rng.subset(n, 3);
                s.sort_unstable();
                s
            },
            Vec::new(),
            (0..n).step_by(7).collect(),
        ];
        let xs: Vec<Vec<f64>> = supports
            .iter()
            .map(|supp| {
                let mut x = vec![0.0; n];
                for (q, &j) in supp.iter().enumerate() {
                    x[j] = 0.2 + q as f64 * 0.3 + rng.gauss() * 0.1;
                }
                x
            })
            .collect();
        let ys: Vec<Vec<f64>> = (0..supports.len())
            .map(|k| (0..b).map(|i| ((i + k) as f64 * 0.53).sin()).collect())
            .collect();
        let alpha = 0.8;
        let row0 = b; // second block
        // singles
        let mut scratch = op.make_scratch();
        let mut want_out: Vec<Vec<f64>> = vec![vec![0.0; n]; supports.len()];
        let mut want_resid: Vec<Vec<f64>> = vec![vec![0.0; b]; supports.len()];
        for k in 0..supports.len() {
            op.block_proxy_step_sparse(
                row0,
                &ys[k],
                &xs[k],
                &supports[k],
                alpha,
                &mut want_resid[k],
                &mut scratch,
                &mut want_out[k],
            );
        }
        // batched
        let mut got_out: Vec<Vec<f64>> = vec![vec![0.0; n]; supports.len()];
        let mut got_resid: Vec<Vec<f64>> = vec![vec![0.0; b]; supports.len()];
        {
            let mut cols: Vec<ProxyCol<'_>> = Vec::new();
            for (((y, x), (supp, resid)), out) in ys
                .iter()
                .zip(&xs)
                .zip(supports.iter().zip(got_resid.iter_mut()))
                .zip(got_out.iter_mut())
            {
                cols.push(ProxyCol {
                    y_b: y,
                    x,
                    support: supp,
                    resid: &mut resid[..],
                    out: &mut out[..],
                });
            }
            op.block_proxy_step_sparse_multi(row0, &mut cols, alpha, &mut scratch);
        }
        for k in 0..supports.len() {
            for i in 0..b {
                assert_eq!(
                    got_resid[k][i].to_bits(),
                    want_resid[k][i].to_bits(),
                    "{}: col {k} resid row {i}",
                    op.name()
                );
            }
            for j in 0..n {
                assert_eq!(
                    got_out[k][j].to_bits(),
                    want_out[k][j].to_bits(),
                    "{}: col {k} out coord {j}",
                    op.name()
                );
            }
        }
    }

    #[test]
    fn proxy_multi_bitwise_parity_both_impls() {
        let (n, b) = (64usize, 8usize);
        let op = dct_op(n, 32, 21);
        let dense = densify(&op);
        check_proxy_multi_matches_single(&Operator::SubsampledDct(op), n, b, 91);
        check_proxy_multi_matches_single(&Operator::Dense(dense), n, b, 91);
    }

    #[test]
    fn proxy_multi_empty_batch_is_a_noop() {
        let op = Operator::SubsampledDct(dct_op(32, 16, 22));
        let mut scratch = op.make_scratch();
        let mut cols: Vec<ProxyCol<'_>> = Vec::new();
        op.block_proxy_step_sparse_multi(0, &mut cols, 1.0, &mut scratch);
    }

    #[test]
    fn apply_multi_matches_per_column_apply() {
        let (n, m) = (64usize, 24usize);
        let op = dct_op(n, m, 23);
        let dense = densify(&op);
        for wrapped in [Operator::SubsampledDct(op), Operator::Dense(dense)] {
            let ncols = 3usize;
            let x_panel: Vec<f64> = (0..ncols * n).map(|i| (i as f64 * 0.17).sin()).collect();
            let mut scratch = wrapped.make_scratch();
            let mut out_panel = vec![0.0; ncols * m];
            wrapped.apply_multi_into(&x_panel, &mut scratch, &mut out_panel);
            for c in 0..ncols {
                let mut want = vec![0.0; m];
                wrapped.apply_into(&x_panel[c * n..(c + 1) * n], &mut scratch, &mut want);
                for i in 0..m {
                    assert_eq!(
                        out_panel[c * m + i].to_bits(),
                        want[i].to_bits(),
                        "{}: col {c} row {i}",
                        wrapped.name()
                    );
                }
            }
        }
    }

    #[test]
    fn dct_residual_and_select_cols_match_dense() {
        let (n, m) = (32usize, 16usize);
        let op = dct_op(n, m, 6);
        let dense = densify(&op);
        let y = wave(m, 5);
        let support = vec![1usize, 8, 30];
        let mut x = vec![0.0; n];
        for &j in &support {
            x[j] = 1.0 + j as f64 * 0.1;
        }
        let mut sd = op.make_scratch();
        let mut none = OpScratch::None;
        let (mut ra, mut rb) = (Vec::new(), Vec::new());
        let got = op.residual_norm_sparse(&y, &x, &support, &mut ra, &mut sd);
        let want = dense.residual_norm_sparse(&y, &x, &support, &mut rb, &mut none);
        assert!((got - want).abs() <= 1e-12 * (1.0 + want), "{got} vs {want}");
        // Column gather: same layout, entrywise bit-identical (same formula).
        let cols = vec![0usize, 7, 8, 31];
        let (mut ga, mut gb) = (Vec::new(), Vec::new());
        op.select_cols_into(&cols, &mut ga);
        dense.select_cols_into(&cols, &mut gb);
        assert_eq!(ga.len(), gb.len());
        for (i, (&a, &b)) in ga.iter().zip(&gb).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "panel entry {i}");
        }
    }

    #[test]
    fn operator_enum_delegates_both_ways() {
        let op = dct_op(32, 8, 7);
        let dense = densify(&op);
        let x = wave(32, 6);
        for (wrapped, name) in
            [(Operator::SubsampledDct(op), "subsampled_dct"), (Operator::Dense(dense), "dense")]
        {
            assert_eq!(wrapped.name(), name);
            assert_eq!(wrapped.rows(), 8);
            assert_eq!(wrapped.cols(), 32);
            let y = wrapped.apply(&x);
            assert_eq!(y.len(), 8);
            assert!(y.iter().all(|v| v.is_finite()));
            assert_eq!(wrapped.dense().is_some(), name == "dense");
        }
    }

    #[test]
    fn adjoint_identity_holds_for_both_impls() {
        // ⟨A x, r⟩ == ⟨x, Aᵀ r⟩ — the property the proptest suite fuzzes;
        // here a deterministic spot check on both implementations.
        let (n, m) = (128usize, 64usize);
        let op = dct_op(n, m, 8);
        let dense = densify(&op);
        let x = wave(n, 7);
        let r = wave(m, 8);
        for wrapped in [Operator::SubsampledDct(op), Operator::Dense(dense)] {
            let mut scratch = wrapped.make_scratch();
            let mut ax = vec![0.0; m];
            wrapped.apply_into(&x, &mut scratch, &mut ax);
            let mut atr = vec![0.0; n];
            wrapped.apply_t_into(&r, &mut scratch, &mut atr);
            let lhs = dot(&ax, &r);
            let rhs = dot(&x, &atr);
            assert!(
                (lhs - rhs).abs() <= 1e-10 * (1.0 + lhs.abs()),
                "{}: {lhs} vs {rhs}",
                wrapped.name()
            );
        }
    }

    #[test]
    fn scratch_upgrades_lazily() {
        // A dense-born scratch handed to the DCT operator must self-upgrade.
        let op = dct_op(16, 8, 9);
        let mut scratch = OpScratch::None;
        let x = wave(16, 9);
        let mut out = vec![0.0; 8];
        op.apply_into(&x, &mut scratch, &mut out);
        assert!(matches!(scratch, OpScratch::Dct(_)));
        assert!(out.iter().all(|v| v.is_finite()));
    }

    #[test]
    #[should_panic(expected = "power of two")]
    fn dct_op_rejects_non_power_of_two() {
        let _ = SubsampledDctOp::new(24, vec![0, 1, 2]);
    }

    #[test]
    #[should_panic(expected = "duplicate row index")]
    fn dct_op_rejects_duplicate_rows() {
        let _ = SubsampledDctOp::new(8, vec![1, 3, 1]);
    }
}
