//! Dense linear-algebra substrate.
//!
//! The paper's cost function is *dense* in `x` (Gaussian `A`), so unlike the
//! HOGWILD!-style literature there is no sparse-matrix machinery here — the
//! substrate is a small, cache-conscious dense BLAS subset plus the two
//! least-squares solvers the greedy baselines need:
//!
//! * [`dense::Mat`] / [`dense::RowBlock`] — row-major storage with zero-copy
//!   measurement-block views and the fused [`dense::RowBlock::proxy_step_into`]
//!   hot-path kernel (the native twin of the Layer-1 Pallas kernel).
//! * [`sparse::SparseIterate`] — iterate values plus an incrementally
//!   maintained sorted support, feeding the sparse fast path
//!   [`dense::RowBlock::proxy_step_sparse_into`] that honors `s ≪ n`.
//! * [`qr::Qr`] — Householder least squares for OMP/CoSaMP/StoGradMP.
//! * [`cgls::cgls`] — iterative least squares (cross-check + large supports).

pub mod cgls;
pub mod dense;
pub mod qr;
pub mod scalar;
pub mod sparse;

pub use cgls::{cgls, CglsResult};
pub use dense::{axpy, dist2, dot, nrm2, scale, sub, Mat, RowBlock};
pub use qr::{lstsq, Qr};
pub use scalar::Scalar;
pub use sparse::SparseIterate;
