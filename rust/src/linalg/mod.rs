//! Linear-algebra substrate: a cache-conscious dense BLAS subset, the two
//! least-squares solvers the greedy baselines need, and the matrix-free
//! measurement-operator layer the solve stack is written against.
//!
//! * [`dense::Mat`] / [`dense::RowBlock`] — row-major storage with zero-copy
//!   measurement-block views and the fused [`dense::RowBlock::proxy_step_into`]
//!   hot-path kernel (the native twin of the Layer-1 Pallas kernel).
//! * [`sparse::SparseIterate`] — iterate values plus an incrementally
//!   maintained sorted support, feeding the sparse fast path
//!   [`dense::RowBlock::proxy_step_sparse_into`] that honors `s ≪ n`.
//! * [`measure::MeasureOp`] — operator access to the ensemble (`A_b x`,
//!   `A_bᵀ r`, fused proxy, sparse gathers, column panels): [`DenseOp`]
//!   wraps a materialized matrix bit-identically, [`SubsampledDctOp`]
//!   evaluates DCT-II rows on the fly via [`fft::DctPlan`] and stores only
//!   `m` row indices — the `n = 10^6` path.
//! * [`fft::DctPlan`] — in-crate O(n log n) FFT (iterative, pair-fused
//!   radix-4, cache-blocked) + DCT-II/III pair, with a process-wide
//!   [`fft::plan_for`] plan cache.
//! * [`simd`] — the explicit-width kernel doorway: runtime
//!   AVX2/NEON/scalar dispatch for dot/axpy/nrm2 and the 4-column panel
//!   dot, bit-identical across levels (`ASTIR_SIMD` overrides the probe).
//! * [`qr::Qr`] — Householder least squares for OMP/CoSaMP/StoGradMP.
//! * [`cgls::cgls`] — iterative least squares (cross-check + large supports).

pub mod cgls;
pub mod dense;
pub mod fft;
pub mod measure;
pub mod qr;
pub mod scalar;
pub mod simd;
pub mod sparse;

pub use cgls::{cgls, CglsResult};
pub use dense::{axpy, dist2, dot, nrm2, scale, sub, Mat, RowBlock};
pub use fft::{plan_for, DctPlan, DctScratch};
pub use measure::{DenseOp, MeasureOp, OpScratch, Operator, ProxyCol, SubsampledDctOp};
pub use qr::{lstsq, Qr};
pub use scalar::Scalar;
pub use sparse::SparseIterate;
