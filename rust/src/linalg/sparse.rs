//! Sparse iterate: dense value storage plus an incrementally-maintained
//! sorted support.
//!
//! The paper's whole premise is `s ≪ n`: StoIHT iterates carry at most
//! `2s` nonzeros (`Γ^t ∪ T̃`), yet the seed kernels treated them as dense
//! vectors and paid `O(n)` per iteration on clears, copies, and the
//! residual pass of the proxy step. [`SparseIterate`] makes the support
//! explicit so the solve stack can do `O(s)` bookkeeping and hand the
//! fused sparse kernel ([`crate::linalg::RowBlock::proxy_step_sparse_into`])
//! the exact column set it needs to gather.
//!
//! Invariant: `values[i] == 0.0` (positive zero) for every `i` outside
//! `support`, and `support` is strictly ascending. All mutation goes
//! through [`SparseIterate::assign_from`] / [`SparseIterate::clear`],
//! which maintain the invariant in `O(|old| + |new|)` — never `O(n)`.

use super::scalar::Scalar;

/// An `n`-dimensional vector that is zero outside a small, sorted support.
#[derive(Clone, Debug, PartialEq)]
pub struct SparseIterate<S: Scalar> {
    values: Vec<S>,
    support: Vec<usize>,
}

impl<S: Scalar> SparseIterate<S> {
    /// The all-zero iterate of dimension `n` (empty support).
    pub fn zeros(n: usize) -> Self {
        SparseIterate { values: vec![S::ZERO; n], support: Vec::new() }
    }

    /// Build from a dense vector; the support is its set of nonzeros.
    pub fn from_dense(v: &[S]) -> Self {
        let support: Vec<usize> = (0..v.len()).filter(|&i| v[i] != S::ZERO).collect();
        SparseIterate { values: v.to_vec(), support }
    }

    /// Ambient dimension `n`.
    #[inline(always)]
    pub fn n(&self) -> usize {
        self.values.len()
    }

    /// Dense view of the values (zero off support).
    #[inline(always)]
    pub fn values(&self) -> &[S] {
        &self.values
    }

    /// The sorted support. May include indices whose value is exactly zero
    /// (e.g. a tally estimate whose proxy coefficient vanished); it is
    /// always a superset of the true nonzero set.
    #[inline(always)]
    pub fn support(&self) -> &[usize] {
        &self.support
    }

    /// Number of supported entries (`<= n`, typically `<= 2s`).
    #[inline(always)]
    pub fn nnz(&self) -> usize {
        self.support.len()
    }

    /// Value at coordinate `i`.
    #[inline(always)]
    pub fn get(&self, i: usize) -> S {
        self.values[i]
    }

    /// Reset to the zero iterate in `O(|support|)`.
    pub fn clear(&mut self) {
        for &i in &self.support {
            self.values[i] = S::ZERO;
        }
        self.support.clear();
    }

    /// Replace the contents with `source` restricted to `new_support`
    /// (strictly ascending). Entries of the old support that are not in the
    /// new one are zeroed; cost is `O(|old| + |new|)`, never `O(n)`.
    pub fn assign_from(&mut self, source: &[S], new_support: &[usize]) {
        debug_assert_eq!(source.len(), self.values.len(), "assign_from: dimension");
        debug_assert!(
            new_support.windows(2).all(|w| w[0] < w[1]),
            "assign_from: support must be strictly ascending"
        );
        for &i in &self.support {
            self.values[i] = S::ZERO;
        }
        self.support.clear();
        self.support.extend_from_slice(new_support);
        for &i in &self.support {
            self.values[i] = source[i];
        }
    }

    /// Replace the contents with the parallel `(support, values)` pairs
    /// (`support` strictly ascending, `values[i]` the entry at
    /// `support[i]`) — the scatter twin of [`SparseIterate::assign_from`]
    /// for producers whose values live in a compact buffer (e.g. a
    /// least-squares solution over a merged support) rather than a dense
    /// source. Cost is `O(|old| + |new|)`, never `O(n)`.
    pub fn assign_pairs(&mut self, support: &[usize], values: &[S]) {
        debug_assert_eq!(support.len(), values.len(), "assign_pairs: parallel slices");
        debug_assert!(
            support.windows(2).all(|w| w[0] < w[1]),
            "assign_pairs: support must be strictly ascending"
        );
        for &i in &self.support {
            self.values[i] = S::ZERO;
        }
        self.support.clear();
        self.support.extend_from_slice(support);
        for (&i, &v) in support.iter().zip(values) {
            self.values[i] = v;
        }
    }

    /// Copy out a dense clone of the values.
    pub fn to_dense(&self) -> Vec<S> {
        self.values.clone()
    }

    /// Consume, returning the dense value vector.
    pub fn into_values(self) -> Vec<S> {
        self.values
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zeros_and_dims() {
        let x = SparseIterate::<f64>::zeros(7);
        assert_eq!(x.n(), 7);
        assert_eq!(x.nnz(), 0);
        assert!(x.values().iter().all(|&v| v == 0.0));
        assert!(x.support().is_empty());
    }

    #[test]
    fn assign_replaces_and_zeroes_old_support() {
        let mut x = SparseIterate::<f64>::zeros(8);
        let src1 = vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0];
        x.assign_from(&src1, &[1, 4]);
        assert_eq!(x.values(), &[0.0, 2.0, 0.0, 0.0, 5.0, 0.0, 0.0, 0.0]);
        assert_eq!(x.support(), &[1, 4]);
        // New assignment drops coordinate 1 entirely.
        x.assign_from(&src1, &[4, 6]);
        assert_eq!(x.values(), &[0.0, 0.0, 0.0, 0.0, 5.0, 0.0, 7.0, 0.0]);
        assert_eq!(x.support(), &[4, 6]);
        assert_eq!(x.nnz(), 2);
    }

    #[test]
    fn support_may_carry_exact_zeros() {
        let mut x = SparseIterate::<f64>::zeros(4);
        x.assign_from(&[0.0, 0.0, 3.0, 0.0], &[1, 2]);
        assert_eq!(x.support(), &[1, 2]);
        assert_eq!(x.get(1), 0.0);
        assert_eq!(x.get(2), 3.0);
    }

    #[test]
    fn assign_pairs_scatters_and_zeroes_old_support() {
        let mut x = SparseIterate::<f64>::zeros(8);
        x.assign_pairs(&[1, 4, 6], &[2.0, 5.0, 7.0]);
        assert_eq!(x.values(), &[0.0, 2.0, 0.0, 0.0, 5.0, 0.0, 7.0, 0.0]);
        assert_eq!(x.support(), &[1, 4, 6]);
        x.assign_pairs(&[0, 4], &[-1.0, 9.0]);
        assert_eq!(x.values(), &[-1.0, 0.0, 0.0, 0.0, 9.0, 0.0, 0.0, 0.0]);
        assert_eq!(x.support(), &[0, 4]);
        x.assign_pairs(&[], &[]);
        assert_eq!(x.nnz(), 0);
        assert!(x.values().iter().all(|&v| v == 0.0));
    }

    #[test]
    fn clear_resets_everything() {
        let mut x = SparseIterate::<f64>::zeros(5);
        x.assign_from(&[9.0; 5], &[0, 3]);
        x.clear();
        assert!(x.values().iter().all(|&v| v == 0.0));
        assert_eq!(x.nnz(), 0);
    }

    #[test]
    fn from_dense_finds_nonzeros() {
        let x = SparseIterate::from_dense(&[0.0f64, -1.5, 0.0, 2.0]);
        assert_eq!(x.support(), &[1, 3]);
        assert_eq!(x.to_dense(), vec![0.0, -1.5, 0.0, 2.0]);
        assert_eq!(x.into_values(), vec![0.0, -1.5, 0.0, 2.0]);
    }

    #[test]
    fn empty_and_full_supports() {
        let mut x = SparseIterate::<f64>::zeros(3);
        x.assign_from(&[1.0, 2.0, 3.0], &[0, 1, 2]);
        assert_eq!(x.values(), &[1.0, 2.0, 3.0]);
        x.assign_from(&[1.0, 2.0, 3.0], &[]);
        assert!(x.values().iter().all(|&v| v == 0.0));
        assert_eq!(x.nnz(), 0);
    }
}
