//! Row-major dense matrix type and the blocked matvec kernels that form the
//! native hot path of every recovery algorithm in this crate.
//!
//! Layout choice: **row-major** — the StoIHT proxy step does one
//! `A_b x` (row-major friendly) and one `A_b^T r`; the transpose matvec is
//! implemented as a row-scaled accumulation so both passes stream `A_b`
//! sequentially (see [`Mat::gemv_t_acc`]), which is what makes the native
//! backend memory-bandwidth-bound rather than cache-miss-bound.

use super::scalar::Scalar;

/// Dense row-major matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct Mat<S: Scalar> {
    rows: usize,
    cols: usize,
    data: Vec<S>,
}

impl<S: Scalar> Mat<S> {
    /// Zero matrix of the given shape.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Mat { rows, cols, data: vec![S::ZERO; rows * cols] }
    }

    /// Build from a row-major data vector. Panics if the length mismatches.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<S>) -> Self {
        assert_eq!(data.len(), rows * cols, "row-major data length mismatch");
        Mat { rows, cols, data }
    }

    /// Build from a function of (row, col).
    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> S) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for i in 0..rows {
            for j in 0..cols {
                data.push(f(i, j));
            }
        }
        Mat { rows, cols, data }
    }

    /// Identity matrix.
    pub fn eye(n: usize) -> Self {
        Self::from_fn(n, n, |i, j| if i == j { S::ONE } else { S::ZERO })
    }

    #[inline(always)]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline(always)]
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Raw row-major data.
    #[inline(always)]
    pub fn data(&self) -> &[S] {
        &self.data
    }

    #[inline(always)]
    pub fn data_mut(&mut self) -> &mut [S] {
        &mut self.data
    }

    #[inline(always)]
    pub fn get(&self, i: usize, j: usize) -> S {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i * self.cols + j]
    }

    #[inline(always)]
    pub fn set(&mut self, i: usize, j: usize, v: S) {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i * self.cols + j] = v;
    }

    /// Row `i` as a slice.
    #[inline(always)]
    pub fn row(&self, i: usize) -> &[S] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline(always)]
    pub fn row_mut(&mut self, i: usize) -> &mut [S] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Borrow rows `r0..r1` as a [`RowBlock`] view (no copy) — this is how
    /// algorithms address the measurement block `A_{b_i}`.
    pub fn row_block(&self, r0: usize, r1: usize) -> RowBlock<'_, S> {
        assert!(r0 <= r1 && r1 <= self.rows, "row block out of range");
        RowBlock {
            rows: r1 - r0,
            cols: self.cols,
            data: &self.data[r0 * self.cols..r1 * self.cols],
        }
    }

    /// The whole matrix as a view.
    pub fn as_block(&self) -> RowBlock<'_, S> {
        self.row_block(0, self.rows)
    }

    /// Copy of column `j`.
    pub fn col_copy(&self, j: usize) -> Vec<S> {
        (0..self.rows).map(|i| self.get(i, j)).collect()
    }

    /// New matrix made of the given columns (in the given order) — used by
    /// OMP/CoSaMP/StoGradMP to form the least-squares submatrix `A_T`.
    pub fn select_cols(&self, cols: &[usize]) -> Mat<S> {
        let mut out = Mat::zeros(self.rows, cols.len());
        for i in 0..self.rows {
            let src = self.row(i);
            let dst = out.row_mut(i);
            for (k, &j) in cols.iter().enumerate() {
                dst[k] = src[j];
            }
        }
        out
    }

    /// `y = A x` (allocating convenience wrapper over the view kernel).
    pub fn gemv(&self, x: &[S]) -> Vec<S> {
        self.as_block().gemv(x)
    }

    /// `y = A^T x`.
    pub fn gemv_t(&self, x: &[S]) -> Vec<S> {
        self.as_block().gemv_t(x)
    }

    /// Cast every element through f64 (used to hand f64-native problems to
    /// the f32 PJRT artifacts).
    pub fn cast<T: Scalar>(&self) -> Mat<T> {
        Mat {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().map(|v| T::from_f64(v.to_f64())).collect(),
        }
    }
}

/// Borrowed row-contiguous block of a [`Mat`] (e.g. the sub-matrix
/// `A_{b_i}` of measurement block `i`).
#[derive(Clone, Copy, Debug)]
pub struct RowBlock<'a, S: Scalar> {
    rows: usize,
    cols: usize,
    data: &'a [S],
}

impl<'a, S: Scalar> RowBlock<'a, S> {
    pub fn from_slice(rows: usize, cols: usize, data: &'a [S]) -> Self {
        assert_eq!(data.len(), rows * cols);
        RowBlock { rows, cols, data }
    }

    #[inline(always)]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline(always)]
    pub fn cols(&self) -> usize {
        self.cols
    }

    #[inline(always)]
    pub fn row(&self, i: usize) -> &[S] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline(always)]
    pub fn data(&self) -> &[S] {
        self.data
    }

    /// `out = A x`, allocating.
    pub fn gemv(&self, x: &[S]) -> Vec<S> {
        let mut out = vec![S::ZERO; self.rows];
        self.gemv_into(x, &mut out);
        out
    }

    /// `out = A x`, no allocation. `x.len() == cols`, `out.len() == rows`.
    ///
    /// Inner loop is 4-way unrolled; with row-major storage each row is a
    /// sequential stream so the hardware prefetcher keeps the FPU fed.
    pub fn gemv_into(&self, x: &[S], out: &mut [S]) {
        assert_eq!(x.len(), self.cols, "gemv: x length");
        assert_eq!(out.len(), self.rows, "gemv: out length");
        for i in 0..self.rows {
            out[i] = dot(self.row(i), x);
        }
    }

    /// `out = A^T x`, allocating.
    pub fn gemv_t(&self, x: &[S]) -> Vec<S> {
        let mut out = vec![S::ZERO; self.cols];
        self.gemv_t_acc(x, S::ZERO, &mut out);
        out
    }

    /// `out = beta * out + A^T x` with **row-sequential** access:
    /// for each row `i`, `out += x[i] * A[i, :]` (an axpy). This streams the
    /// matrix in storage order instead of striding down columns.
    pub fn gemv_t_acc(&self, x: &[S], beta: S, out: &mut [S]) {
        assert_eq!(x.len(), self.rows, "gemv_t: x length");
        assert_eq!(out.len(), self.cols, "gemv_t: out length");
        if beta != S::ONE {
            if beta == S::ZERO {
                out.fill(S::ZERO);
            } else {
                for o in out.iter_mut() {
                    *o *= beta;
                }
            }
        }
        for i in 0..self.rows {
            let xi = x[i];
            if xi == S::ZERO {
                continue;
            }
            axpy(xi, self.row(i), out);
        }
    }

    /// Fused StoIHT proxy kernel: `out = x + alpha * A^T (y - A x)` with a
    /// caller-provided residual scratch (`scratch.len() == rows`). This is
    /// the native twin of the Layer-1 Pallas kernel and the single hottest
    /// function in the crate — zero allocation, two sequential passes over
    /// the block.
    pub fn proxy_step_into(&self, y: &[S], x: &[S], alpha: S, scratch: &mut [S], out: &mut [S]) {
        assert_eq!(y.len(), self.rows);
        assert_eq!(scratch.len(), self.rows);
        assert_eq!(out.len(), self.cols);
        // pass 1: scratch = y - A x
        for i in 0..self.rows {
            scratch[i] = y[i] - dot(self.row(i), x);
        }
        // pass 2: out = x + alpha * A^T scratch
        out.copy_from_slice(x);
        for i in 0..self.rows {
            let w = alpha * scratch[i];
            if w == S::ZERO {
                continue;
            }
            axpy(w, self.row(i), out);
        }
    }
}

/// Dot product, 4-way unrolled with independent accumulators so the adds
/// pipeline (and the compiler can vectorize under `-C opt-level=3`).
#[inline]
pub fn dot<S: Scalar>(a: &[S], b: &[S]) -> S {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let chunks = n / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (S::ZERO, S::ZERO, S::ZERO, S::ZERO);
    for k in 0..chunks {
        let i = 4 * k;
        s0 += a[i] * b[i];
        s1 += a[i + 1] * b[i + 1];
        s2 += a[i + 2] * b[i + 2];
        s3 += a[i + 3] * b[i + 3];
    }
    let mut s = (s0 + s1) + (s2 + s3);
    for i in 4 * chunks..n {
        s += a[i] * b[i];
    }
    s
}

/// `y += a * x` (axpy), unrolled like [`dot`].
#[inline]
pub fn axpy<S: Scalar>(a: S, x: &[S], y: &mut [S]) {
    debug_assert_eq!(x.len(), y.len());
    let n = x.len();
    let chunks = n / 4;
    for k in 0..chunks {
        let i = 4 * k;
        y[i] += a * x[i];
        y[i + 1] += a * x[i + 1];
        y[i + 2] += a * x[i + 2];
        y[i + 3] += a * x[i + 3];
    }
    for i in 4 * chunks..n {
        y[i] += a * x[i];
    }
}

/// Euclidean norm.
#[inline]
pub fn nrm2<S: Scalar>(v: &[S]) -> S {
    dot(v, v).sqrt()
}

/// `a - b`, allocating.
pub fn sub<S: Scalar>(a: &[S], b: &[S]) -> Vec<S> {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(&p, &q)| p - q).collect()
}

/// `||a - b||_2` without allocating.
pub fn dist2<S: Scalar>(a: &[S], b: &[S]) -> S {
    debug_assert_eq!(a.len(), b.len());
    let mut s = S::ZERO;
    for i in 0..a.len() {
        let d = a[i] - b[i];
        s += d * d;
    }
    s.sqrt()
}

/// Scale in place.
pub fn scale<S: Scalar>(v: &mut [S], a: S) {
    for x in v.iter_mut() {
        *x *= a;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn approx(a: f64, b: f64, tol: f64) {
        assert!((a - b).abs() <= tol, "{a} vs {b}");
    }

    #[test]
    fn construction_and_access() {
        let m = Mat::<f64>::from_fn(3, 2, |i, j| (i * 10 + j) as f64);
        assert_eq!(m.rows(), 3);
        assert_eq!(m.cols(), 2);
        assert_eq!(m.get(2, 1), 21.0);
        assert_eq!(m.row(1), &[10.0, 11.0]);
        assert_eq!(m.col_copy(1), vec![1.0, 11.0, 21.0]);
    }

    #[test]
    #[should_panic(expected = "length mismatch")]
    fn from_vec_checks_len() {
        let _ = Mat::<f64>::from_vec(2, 2, vec![1.0, 2.0, 3.0]);
    }

    #[test]
    fn eye_gemv_is_identity() {
        let m = Mat::<f64>::eye(5);
        let x = vec![1.0, -2.0, 3.0, 0.5, 4.0];
        assert_eq!(m.gemv(&x), x);
        assert_eq!(m.gemv_t(&x), x);
    }

    #[test]
    fn gemv_matches_manual() {
        // [[1,2,3],[4,5,6]] @ [1,1,2] = [9, 21]
        let m = Mat::from_vec(2, 3, vec![1.0f64, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert_eq!(m.gemv(&[1.0, 1.0, 2.0]), vec![9.0, 21.0]);
        // A^T [1, 2] = [9, 12, 15]
        assert_eq!(m.gemv_t(&[1.0, 2.0]), vec![9.0, 12.0, 15.0]);
    }

    #[test]
    fn row_block_view() {
        let m = Mat::<f64>::from_fn(6, 3, |i, j| (i * 3 + j) as f64);
        let blk = m.row_block(2, 4);
        assert_eq!(blk.rows(), 2);
        assert_eq!(blk.row(0), m.row(2));
        assert_eq!(blk.row(1), m.row(3));
        let x = vec![1.0, 0.0, -1.0];
        let full = m.gemv(&x);
        assert_eq!(blk.gemv(&x), &full[2..4]);
    }

    #[test]
    fn gemv_t_acc_beta() {
        let m = Mat::from_vec(2, 2, vec![1.0f64, 2.0, 3.0, 4.0]);
        let mut out = vec![10.0, 20.0];
        // out = 0.5*out + A^T [1,1] = [5,10] + [4,6] = [9,16]
        m.as_block().gemv_t_acc(&[1.0, 1.0], 0.5, &mut out);
        assert_eq!(out, vec![9.0, 16.0]);
    }

    #[test]
    fn proxy_step_matches_composition() {
        let m = Mat::<f64>::from_fn(4, 7, |i, j| ((i * 7 + j) as f64 * 0.13).sin());
        let x: Vec<f64> = (0..7).map(|i| (i as f64 * 0.71).cos()).collect();
        let y: Vec<f64> = (0..4).map(|i| (i as f64 * 0.37).sin()).collect();
        let alpha = 0.8;
        let blk = m.as_block();
        let mut scratch = vec![0.0; 4];
        let mut out = vec![0.0; 7];
        blk.proxy_step_into(&y, &x, alpha, &mut scratch, &mut out);
        // reference composition
        let ax = blk.gemv(&x);
        let r: Vec<f64> = y.iter().zip(&ax).map(|(&a, &b)| a - b).collect();
        let atr = blk.gemv_t(&r);
        for j in 0..7 {
            approx(out[j], x[j] + alpha * atr[j], 1e-12);
        }
    }

    #[test]
    fn select_cols_permutes() {
        let m = Mat::<f64>::from_fn(2, 4, |i, j| (i * 4 + j) as f64);
        let sel = m.select_cols(&[3, 0]);
        assert_eq!(sel.row(0), &[3.0, 0.0]);
        assert_eq!(sel.row(1), &[7.0, 4.0]);
    }

    #[test]
    fn dot_axpy_odd_lengths() {
        // exercise the remainder loop (n % 4 != 0)
        for n in [1usize, 2, 3, 5, 7, 9] {
            let a: Vec<f64> = (0..n).map(|i| i as f64 + 1.0).collect();
            let b: Vec<f64> = (0..n).map(|i| (i as f64) * 0.5).collect();
            let want: f64 = (0..n).map(|i| (i as f64 + 1.0) * (i as f64) * 0.5).sum();
            approx(dot(&a, &b), want, 1e-12);
            let mut y = vec![1.0; n];
            axpy(2.0, &a, &mut y);
            for i in 0..n {
                approx(y[i], 1.0 + 2.0 * (i as f64 + 1.0), 1e-12);
            }
        }
    }

    #[test]
    fn norms_and_dist() {
        approx(nrm2(&[3.0f64, 4.0]), 5.0, 1e-15);
        approx(dist2(&[1.0f64, 2.0], &[4.0, 6.0]), 5.0, 1e-15);
        assert_eq!(sub(&[3.0f64, 4.0], &[1.0, 1.0]), vec![2.0, 3.0]);
    }

    #[test]
    fn cast_roundtrip() {
        let m = Mat::<f64>::from_fn(2, 2, |i, j| (i + j) as f64 + 0.25);
        let f: Mat<f32> = m.cast();
        let back: Mat<f64> = f.cast();
        assert_eq!(m, back);
    }
}
