//! Row-major dense matrix type and the blocked matvec kernels that form the
//! native hot path of every recovery algorithm in this crate.
//!
//! Layout choice: **row-major** — the StoIHT proxy step does one
//! `A_b x` (row-major friendly) and one `A_b^T r`; the transpose matvec is
//! implemented as a row-scaled accumulation so both passes stream `A_b`
//! sequentially (see [`Mat::gemv_t_acc`]), which is what makes the native
//! backend memory-bandwidth-bound rather than cache-miss-bound.

use super::scalar::Scalar;

/// Dense row-major matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct Mat<S: Scalar> {
    rows: usize,
    cols: usize,
    data: Vec<S>,
}

impl<S: Scalar> Mat<S> {
    /// Zero matrix of the given shape.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Mat { rows, cols, data: vec![S::ZERO; rows * cols] }
    }

    /// Build from a row-major data vector. Panics if the length mismatches.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<S>) -> Self {
        assert_eq!(data.len(), rows * cols, "row-major data length mismatch");
        Mat { rows, cols, data }
    }

    /// Build from a function of (row, col).
    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> S) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for i in 0..rows {
            for j in 0..cols {
                data.push(f(i, j));
            }
        }
        Mat { rows, cols, data }
    }

    /// Identity matrix.
    pub fn eye(n: usize) -> Self {
        Self::from_fn(n, n, |i, j| if i == j { S::ONE } else { S::ZERO })
    }

    #[inline(always)]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline(always)]
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Raw row-major data.
    #[inline(always)]
    pub fn data(&self) -> &[S] {
        &self.data
    }

    #[inline(always)]
    pub fn data_mut(&mut self) -> &mut [S] {
        &mut self.data
    }

    #[inline(always)]
    pub fn get(&self, i: usize, j: usize) -> S {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i * self.cols + j]
    }

    #[inline(always)]
    pub fn set(&mut self, i: usize, j: usize, v: S) {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i * self.cols + j] = v;
    }

    /// Row `i` as a slice.
    #[inline(always)]
    pub fn row(&self, i: usize) -> &[S] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline(always)]
    pub fn row_mut(&mut self, i: usize) -> &mut [S] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Borrow rows `r0..r1` as a [`RowBlock`] view (no copy) — this is how
    /// algorithms address the measurement block `A_{b_i}`.
    pub fn row_block(&self, r0: usize, r1: usize) -> RowBlock<'_, S> {
        assert!(r0 <= r1 && r1 <= self.rows, "row block out of range");
        RowBlock {
            rows: r1 - r0,
            cols: self.cols,
            data: &self.data[r0 * self.cols..r1 * self.cols],
        }
    }

    /// The whole matrix as a view.
    pub fn as_block(&self) -> RowBlock<'_, S> {
        self.row_block(0, self.rows)
    }

    /// Copy of column `j`.
    pub fn col_copy(&self, j: usize) -> Vec<S> {
        (0..self.rows).map(|i| self.get(i, j)).collect()
    }

    /// New matrix made of the given columns (in the given order) — used by
    /// OMP/CoSaMP/StoGradMP to form the least-squares submatrix `A_T`.
    pub fn select_cols(&self, cols: &[usize]) -> Mat<S> {
        let mut out = Mat::zeros(self.rows, cols.len());
        for i in 0..self.rows {
            let src = self.row(i);
            let dst = out.row_mut(i);
            for (k, &j) in cols.iter().enumerate() {
                dst[k] = src[j];
            }
        }
        out
    }

    /// Allocation-free twin of [`Mat::select_cols`]: gather the selected
    /// columns (row-major, same element order) into a reused buffer,
    /// cleared first. [`Mat::from_vec`] turns the buffer into the submatrix
    /// and [`Mat::into_data`] reclaims it — the StoGradMP kernel's re-fit
    /// cycles one buffer this way instead of allocating per iteration.
    pub fn select_cols_into(&self, cols: &[usize], out: &mut Vec<S>) {
        out.clear();
        out.reserve(self.rows * cols.len());
        for i in 0..self.rows {
            let src = self.row(i);
            for &j in cols {
                out.push(src[j]);
            }
        }
    }

    /// Consume, returning the row-major data vector.
    pub fn into_data(self) -> Vec<S> {
        self.data
    }

    /// `y = A x` (allocating convenience wrapper over the view kernel).
    pub fn gemv(&self, x: &[S]) -> Vec<S> {
        self.as_block().gemv(x)
    }

    /// `y = A^T x`.
    pub fn gemv_t(&self, x: &[S]) -> Vec<S> {
        self.as_block().gemv_t(x)
    }

    /// Cast every element through f64 (used to hand f64-native problems to
    /// the f32 PJRT artifacts).
    pub fn cast<T: Scalar>(&self) -> Mat<T> {
        Mat {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().map(|v| T::from_f64(v.to_f64())).collect(),
        }
    }
}

/// Borrowed row-contiguous block of a [`Mat`] (e.g. the sub-matrix
/// `A_{b_i}` of measurement block `i`).
#[derive(Clone, Copy, Debug)]
pub struct RowBlock<'a, S: Scalar> {
    rows: usize,
    cols: usize,
    data: &'a [S],
}

impl<'a, S: Scalar> RowBlock<'a, S> {
    pub fn from_slice(rows: usize, cols: usize, data: &'a [S]) -> Self {
        assert_eq!(data.len(), rows * cols);
        RowBlock { rows, cols, data }
    }

    #[inline(always)]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline(always)]
    pub fn cols(&self) -> usize {
        self.cols
    }

    #[inline(always)]
    pub fn row(&self, i: usize) -> &[S] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline(always)]
    pub fn data(&self) -> &[S] {
        self.data
    }

    /// `out = A x`, allocating.
    pub fn gemv(&self, x: &[S]) -> Vec<S> {
        let mut out = vec![S::ZERO; self.rows];
        self.gemv_into(x, &mut out);
        out
    }

    /// `out = A x`, no allocation. `x.len() == cols`, `out.len() == rows`.
    ///
    /// Inner loop is 4-way unrolled; with row-major storage each row is a
    /// sequential stream so the hardware prefetcher keeps the FPU fed.
    pub fn gemv_into(&self, x: &[S], out: &mut [S]) {
        assert_eq!(x.len(), self.cols, "gemv: x length");
        assert_eq!(out.len(), self.rows, "gemv: out length");
        for i in 0..self.rows {
            out[i] = dot(self.row(i), x);
        }
    }

    /// `out = A^T x`, allocating.
    pub fn gemv_t(&self, x: &[S]) -> Vec<S> {
        let mut out = vec![S::ZERO; self.cols];
        self.gemv_t_acc(x, S::ZERO, &mut out);
        out
    }

    /// `out = beta * out + A^T x` with **row-sequential** access:
    /// for each row `i`, `out += x[i] * A[i, :]` (an axpy). This streams the
    /// matrix in storage order instead of striding down columns.
    pub fn gemv_t_acc(&self, x: &[S], beta: S, out: &mut [S]) {
        assert_eq!(x.len(), self.rows, "gemv_t: x length");
        assert_eq!(out.len(), self.cols, "gemv_t: out length");
        if beta != S::ONE {
            if beta == S::ZERO {
                out.fill(S::ZERO);
            } else {
                for o in out.iter_mut() {
                    *o *= beta;
                }
            }
        }
        for i in 0..self.rows {
            let xi = x[i];
            if xi == S::ZERO {
                continue;
            }
            axpy(xi, self.row(i), out);
        }
    }

    /// Fused StoIHT proxy kernel: `out = x + alpha * A^T (y - A x)` with a
    /// caller-provided residual scratch (`scratch.len() == rows`). This is
    /// the native twin of the Layer-1 Pallas kernel and the single hottest
    /// function in the crate — zero allocation, two sequential passes over
    /// the block.
    pub fn proxy_step_into(&self, y: &[S], x: &[S], alpha: S, scratch: &mut [S], out: &mut [S]) {
        assert_eq!(y.len(), self.rows);
        assert_eq!(scratch.len(), self.rows);
        assert_eq!(out.len(), self.cols);
        // pass 1: scratch = y - A x
        for i in 0..self.rows {
            scratch[i] = y[i] - dot(self.row(i), x);
        }
        // pass 2: out = x + alpha * A^T scratch
        out.copy_from_slice(x);
        for i in 0..self.rows {
            let w = alpha * scratch[i];
            if w == S::ZERO {
                continue;
            }
            axpy(w, self.row(i), out);
        }
    }

    /// Sparse-iterate twin of [`RowBlock::proxy_step_into`], exploiting a
    /// known support of `x`: the residual pass gathers only the supported
    /// columns of `A_b` — `O(rows * |support|)` instead of `O(rows * cols)`
    /// — via `a_t`, the transposed copy of the *full* matrix, whose row `j`
    /// holds column `j` of `A` contiguously (the same layout trick the
    /// sparse exit check uses). `row0` is this block's first row within the
    /// full matrix, so column `j` of `A_b` is `a_t.row(j)[row0 .. row0+rows]`.
    ///
    /// Bit-for-bit contract: when `x[j] == +0.0` for every `j ∉ support`
    /// (the [`super::sparse::SparseIterate`] invariant) and `support` is
    /// strictly ascending, `out` is **bit-identical** to what
    /// `proxy_step_into` produces on the dense `x`. Pass 1 replicates
    /// [`dot`]'s 4-lane accumulation order over the surviving terms (adding
    /// `±0.0` products to lanes that are never `-0.0` is an IEEE identity),
    /// and pass 2 performs the identical row-ordered axpy sequence — only
    /// column-blocked so `out` stays cache-resident while `A_b` streams.
    #[allow(clippy::too_many_arguments)]
    pub fn proxy_step_sparse_into(
        &self,
        a_t: &Mat<S>,
        row0: usize,
        y: &[S],
        x: &[S],
        support: &[usize],
        alpha: S,
        scratch: &mut [S],
        out: &mut [S],
    ) {
        let b = self.rows;
        let n = self.cols;
        assert_eq!(out.len(), n, "proxy_step_sparse: out length");
        // pass 1: scratch = y - A_b x over the supported columns only.
        self.residual_sparse_into(a_t, row0, y, x, support, scratch);
        // pass 2: out = x + alpha * A_b^T scratch. Same per-coordinate row
        // order as the dense kernel (axpy is elementwise, so the column
        // blocking below cannot change any result bit); `x` is scattered
        // sparsely instead of copied densely.
        out.fill(S::ZERO);
        for &j in support {
            out[j] = x[j];
        }
        const CHUNK: usize = 1024;
        let mut c0 = 0usize;
        while c0 < n {
            let c1 = (c0 + CHUNK).min(n);
            for i in 0..b {
                let w = alpha * scratch[i];
                if w == S::ZERO {
                    continue;
                }
                axpy(w, &self.row(i)[c0..c1], &mut out[c0..c1]);
            }
            c0 = c1;
        }
    }

    /// The sparse proxy kernel's residual pass on its own:
    /// `scratch = y − A_b x` gathering only the supported columns of `A_b`
    /// via the transposed copy `a_t` (see
    /// [`RowBlock::proxy_step_sparse_into`] for the layout contract).
    /// Shared by the StoIHT proxy and the StoGradMP identify phase.
    ///
    /// Bit-for-bit contract: under the `SparseIterate` invariant
    /// (`x[j] == +0.0` off a strictly ascending `support`), `scratch` is
    /// bit-identical to the dense `y[i] − dot(row_i, x)` — the gather
    /// replicates [`dot`]'s 4-lane accumulation order over the surviving
    /// terms (lane = column index mod 4, tail past `4*(n/4)` folded in
    /// sequentially after the lane merge).
    pub fn residual_sparse_into(
        &self,
        a_t: &Mat<S>,
        row0: usize,
        y: &[S],
        x: &[S],
        support: &[usize],
        scratch: &mut [S],
    ) {
        let b = self.rows;
        let n = self.cols;
        assert_eq!(y.len(), b, "residual_sparse: y length");
        assert_eq!(x.len(), n, "residual_sparse: x length");
        assert_eq!(scratch.len(), b, "residual_sparse: scratch length");
        assert_eq!(a_t.rows(), n, "residual_sparse: a_t must be the n x m transpose");
        assert!(row0 + b <= a_t.cols(), "residual_sparse: row window out of range");
        debug_assert!(
            support.windows(2).all(|w| w[0] < w[1]),
            "residual_sparse: support must be strictly ascending"
        );
        let m = a_t.cols();
        let at = a_t.data();
        let split = 4 * (n / 4);
        let tail_start = support.partition_point(|&j| j < split);
        for i in 0..b {
            let base = row0 + i;
            let (mut s0, mut s1, mut s2, mut s3) = (S::ZERO, S::ZERO, S::ZERO, S::ZERO);
            for &j in &support[..tail_start] {
                let t = at[j * m + base] * x[j];
                match j & 3 {
                    0 => s0 += t,
                    1 => s1 += t,
                    2 => s2 += t,
                    _ => s3 += t,
                }
            }
            let mut s = (s0 + s1) + (s2 + s3);
            for &j in &support[tail_start..] {
                s += at[j * m + base] * x[j];
            }
            scratch[i] = y[i] - s;
        }
    }
}

/// Dot product, 4-way unrolled with independent accumulators so the adds
/// pipeline (and the compiler can vectorize under `-C opt-level=3`).
///
/// `f64` calls route through the [`super::simd`] doorway
/// ([`Scalar::simd_dot`]) — explicit-width AVX2/NEON kernels that preserve
/// this loop's exact accumulation order (lane = index mod 4, lanes reduced
/// `(s0+s1)+(s2+s3)`, sequential tail), so dispatch never changes a bit of
/// the result. `f32` keeps the generic loop below.
#[inline]
pub fn dot<S: Scalar>(a: &[S], b: &[S]) -> S {
    debug_assert_eq!(a.len(), b.len());
    if let Some(s) = S::simd_dot(a, b) {
        return s;
    }
    let n = a.len();
    let chunks = n / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (S::ZERO, S::ZERO, S::ZERO, S::ZERO);
    for k in 0..chunks {
        let i = 4 * k;
        s0 += a[i] * b[i];
        s1 += a[i + 1] * b[i + 1];
        s2 += a[i + 2] * b[i + 2];
        s3 += a[i + 3] * b[i + 3];
    }
    let mut s = (s0 + s1) + (s2 + s3);
    for i in 4 * chunks..n {
        s += a[i] * b[i];
    }
    s
}

/// `y += a * x` (axpy), unrolled like [`dot`]. `f64` routes through the
/// [`super::simd`] doorway ([`Scalar::simd_axpy`]); elementwise, so every
/// dispatch level is bit-identical by construction.
#[inline]
pub fn axpy<S: Scalar>(a: S, x: &[S], y: &mut [S]) {
    debug_assert_eq!(x.len(), y.len());
    if S::simd_axpy(a, x, y) {
        return;
    }
    let n = x.len();
    let chunks = n / 4;
    for k in 0..chunks {
        let i = 4 * k;
        y[i] += a * x[i];
        y[i + 1] += a * x[i + 1];
        y[i + 2] += a * x[i + 2];
        y[i + 3] += a * x[i + 3];
    }
    for i in 4 * chunks..n {
        y[i] += a * x[i];
    }
}

/// Euclidean norm.
#[inline]
pub fn nrm2<S: Scalar>(v: &[S]) -> S {
    dot(v, v).sqrt()
}

/// `a - b`, allocating.
pub fn sub<S: Scalar>(a: &[S], b: &[S]) -> Vec<S> {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(&p, &q)| p - q).collect()
}

/// `||a - b||_2` without allocating.
pub fn dist2<S: Scalar>(a: &[S], b: &[S]) -> S {
    debug_assert_eq!(a.len(), b.len());
    let mut s = S::ZERO;
    for i in 0..a.len() {
        let d = a[i] - b[i];
        s += d * d;
    }
    s.sqrt()
}

/// Scale in place.
pub fn scale<S: Scalar>(v: &mut [S], a: S) {
    for x in v.iter_mut() {
        *x *= a;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn approx(a: f64, b: f64, tol: f64) {
        assert!((a - b).abs() <= tol, "{a} vs {b}");
    }

    #[test]
    fn construction_and_access() {
        let m = Mat::<f64>::from_fn(3, 2, |i, j| (i * 10 + j) as f64);
        assert_eq!(m.rows(), 3);
        assert_eq!(m.cols(), 2);
        assert_eq!(m.get(2, 1), 21.0);
        assert_eq!(m.row(1), &[10.0, 11.0]);
        assert_eq!(m.col_copy(1), vec![1.0, 11.0, 21.0]);
    }

    #[test]
    #[should_panic(expected = "length mismatch")]
    fn from_vec_checks_len() {
        let _ = Mat::<f64>::from_vec(2, 2, vec![1.0, 2.0, 3.0]);
    }

    #[test]
    fn eye_gemv_is_identity() {
        let m = Mat::<f64>::eye(5);
        let x = vec![1.0, -2.0, 3.0, 0.5, 4.0];
        assert_eq!(m.gemv(&x), x);
        assert_eq!(m.gemv_t(&x), x);
    }

    #[test]
    fn gemv_matches_manual() {
        // [[1,2,3],[4,5,6]] @ [1,1,2] = [9, 21]
        let m = Mat::from_vec(2, 3, vec![1.0f64, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert_eq!(m.gemv(&[1.0, 1.0, 2.0]), vec![9.0, 21.0]);
        // A^T [1, 2] = [9, 12, 15]
        assert_eq!(m.gemv_t(&[1.0, 2.0]), vec![9.0, 12.0, 15.0]);
    }

    #[test]
    fn row_block_view() {
        let m = Mat::<f64>::from_fn(6, 3, |i, j| (i * 3 + j) as f64);
        let blk = m.row_block(2, 4);
        assert_eq!(blk.rows(), 2);
        assert_eq!(blk.row(0), m.row(2));
        assert_eq!(blk.row(1), m.row(3));
        let x = vec![1.0, 0.0, -1.0];
        let full = m.gemv(&x);
        assert_eq!(blk.gemv(&x), &full[2..4]);
    }

    #[test]
    fn gemv_t_acc_beta() {
        let m = Mat::from_vec(2, 2, vec![1.0f64, 2.0, 3.0, 4.0]);
        let mut out = vec![10.0, 20.0];
        // out = 0.5*out + A^T [1,1] = [5,10] + [4,6] = [9,16]
        m.as_block().gemv_t_acc(&[1.0, 1.0], 0.5, &mut out);
        assert_eq!(out, vec![9.0, 16.0]);
    }

    #[test]
    fn proxy_step_matches_composition() {
        let m = Mat::<f64>::from_fn(4, 7, |i, j| ((i * 7 + j) as f64 * 0.13).sin());
        let x: Vec<f64> = (0..7).map(|i| (i as f64 * 0.71).cos()).collect();
        let y: Vec<f64> = (0..4).map(|i| (i as f64 * 0.37).sin()).collect();
        let alpha = 0.8;
        let blk = m.as_block();
        let mut scratch = vec![0.0; 4];
        let mut out = vec![0.0; 7];
        blk.proxy_step_into(&y, &x, alpha, &mut scratch, &mut out);
        // reference composition
        let ax = blk.gemv(&x);
        let r: Vec<f64> = y.iter().zip(&ax).map(|(&a, &b)| a - b).collect();
        let atr = blk.gemv_t(&r);
        for j in 0..7 {
            approx(out[j], x[j] + alpha * atr[j], 1e-12);
        }
    }

    #[test]
    fn sparse_proxy_matches_dense_bitwise() {
        // Full matrix 12x9 split into 3 blocks of 4 rows; x sparse.
        let (m, n, b) = (12usize, 9usize, 4usize);
        let a = Mat::<f64>::from_fn(m, n, |i, j| ((i * n + j) as f64 * 0.29).sin());
        let a_t = Mat::<f64>::from_fn(n, m, |i, j| a.get(j, i));
        let supports: [&[usize]; 5] =
            [&[], &[0], &[2, 5, 8], &[0, 1, 2, 3, 4, 5, 6, 7, 8], &[7, 8]];
        for (k, supp) in supports.iter().enumerate() {
            let mut x = vec![0.0f64; n];
            for (q, &j) in supp.iter().enumerate() {
                x[j] = ((q + k) as f64 * 0.61).cos();
            }
            for block in 0..m / b {
                let row0 = block * b;
                let blk = a.row_block(row0, row0 + b);
                let y: Vec<f64> = (0..b).map(|i| ((row0 + i) as f64 * 0.37).sin()).collect();
                let mut scr_d = vec![0.0; b];
                let mut out_d = vec![0.0; n];
                blk.proxy_step_into(&y, &x, 0.8, &mut scr_d, &mut out_d);
                let mut scr_s = vec![0.0; b];
                let mut out_s = vec![0.0; n];
                blk.proxy_step_sparse_into(&a_t, row0, &y, &x, supp, 0.8, &mut scr_s, &mut out_s);
                for i in 0..b {
                    assert_eq!(
                        scr_d[i].to_bits(),
                        scr_s[i].to_bits(),
                        "case {k} block {block} residual row {i}"
                    );
                }
                for j in 0..n {
                    assert_eq!(
                        out_d[j].to_bits(),
                        out_s[j].to_bits(),
                        "case {k} block {block} coord {j}"
                    );
                }
            }
        }
    }

    #[test]
    fn sparse_proxy_chunking_is_exact_at_large_n() {
        // n past the 1024-column chunk boundary: blocking must not change bits.
        let (n, b) = (2500usize, 3usize);
        let a = Mat::<f64>::from_fn(b, n, |i, j| ((i * n + j) as f64 * 0.013).sin());
        let a_t = Mat::<f64>::from_fn(n, b, |i, j| a.get(j, i));
        let supp: Vec<usize> = (0..20)
            .map(|k| k * 117 % n)
            .collect::<std::collections::BTreeSet<_>>()
            .into_iter()
            .collect();
        let mut x = vec![0.0f64; n];
        for (q, &j) in supp.iter().enumerate() {
            x[j] = (q as f64 * 0.7).sin() + 0.1;
        }
        let y: Vec<f64> = (0..b).map(|i| (i as f64 * 0.9).cos()).collect();
        let blk = a.as_block();
        let (mut scr_d, mut out_d) = (vec![0.0; b], vec![0.0; n]);
        blk.proxy_step_into(&y, &x, 1.0, &mut scr_d, &mut out_d);
        let (mut scr_s, mut out_s) = (vec![0.0; b], vec![0.0; n]);
        blk.proxy_step_sparse_into(&a_t, 0, &y, &x, &supp, 1.0, &mut scr_s, &mut out_s);
        for j in 0..n {
            assert_eq!(out_d[j].to_bits(), out_s[j].to_bits(), "coord {j}");
        }
    }

    #[test]
    fn select_cols_permutes() {
        let m = Mat::<f64>::from_fn(2, 4, |i, j| (i * 4 + j) as f64);
        let sel = m.select_cols(&[3, 0]);
        assert_eq!(sel.row(0), &[3.0, 0.0]);
        assert_eq!(sel.row(1), &[7.0, 4.0]);
    }

    #[test]
    fn dot_axpy_odd_lengths() {
        // exercise the remainder loop (n % 4 != 0)
        for n in [1usize, 2, 3, 5, 7, 9] {
            let a: Vec<f64> = (0..n).map(|i| i as f64 + 1.0).collect();
            let b: Vec<f64> = (0..n).map(|i| (i as f64) * 0.5).collect();
            let want: f64 = (0..n).map(|i| (i as f64 + 1.0) * (i as f64) * 0.5).sum();
            approx(dot(&a, &b), want, 1e-12);
            let mut y = vec![1.0; n];
            axpy(2.0, &a, &mut y);
            for i in 0..n {
                approx(y[i], 1.0 + 2.0 * (i as f64 + 1.0), 1e-12);
            }
        }
    }

    #[test]
    fn norms_and_dist() {
        approx(nrm2(&[3.0f64, 4.0]), 5.0, 1e-15);
        approx(dist2(&[1.0f64, 2.0], &[4.0, 6.0]), 5.0, 1e-15);
        assert_eq!(sub(&[3.0f64, 4.0], &[1.0, 1.0]), vec![2.0, 3.0]);
    }

    #[test]
    fn cast_roundtrip() {
        let m = Mat::<f64>::from_fn(2, 2, |i, j| (i + j) as f64 + 0.25);
        let f: Mat<f32> = m.cast();
        let back: Mat<f64> = f.cast();
        assert_eq!(m, back);
    }
}
