//! Report emission: every experiment driver funnels its table through
//! [`emit`], which prints the aligned text (what the paper's figure shows)
//! and persists the series under `results/` as both CSV and JSON so it can
//! be re-plotted or machine-diffed.

use std::path::{Path, PathBuf};

use crate::metrics::Table;
use crate::sync::atomic::{AtomicBool, Ordering};

/// Directory for CSV/JSON outputs: `$ASTIR_RESULTS` or `./results`.
pub fn results_dir() -> PathBuf {
    std::env::var_os("ASTIR_RESULTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("results"))
}

/// Paths written by [`emit`]; `None` where the write failed (read-only
/// results dir — the CI case).
#[derive(Clone, Debug, Default)]
pub struct Emitted {
    pub csv: Option<PathBuf>,
    pub json: Option<PathBuf>,
}

// A bench run emits many tables; an unwritable results dir should cost one
// warning line, not one per table.
static WRITE_WARNED: AtomicBool = AtomicBool::new(false);

fn warn_once(path: &Path, e: &std::io::Error) {
    // Relaxed: a once-flag guarding a warning line; no data is published.
    if !WRITE_WARNED.swap(true, Ordering::Relaxed) {
        eprintln!(
            "[warn] could not write {} ({e}); further results-dir write warnings suppressed",
            path.display()
        );
    }
}

/// Print a titled, aligned table and write `results/<name>.csv` plus
/// `results/<name>.json`. Returns the written paths (best-effort: IO
/// errors degrade to a single process-wide warning, and benches still
/// print their numbers on read-only filesystems).
pub fn emit(name: &str, title: &str, table: &Table) -> Emitted {
    println!("\n--- {title} ---");
    print!("{}", table.to_aligned());
    let dir = results_dir();
    let csv_path = dir.join(format!("{name}.csv"));
    let json_path = dir.join(format!("{name}.json"));
    let csv = match table.write_csv(&csv_path) {
        Ok(()) => Some(csv_path),
        Err(e) => {
            warn_once(&csv_path, &e);
            None
        }
    };
    let json = match table.write_json(&json_path) {
        Ok(()) => Some(json_path),
        Err(e) => {
            warn_once(&json_path, &e);
            None
        }
    };
    if let (Some(c), Some(j)) = (&csv, &json) {
        println!("[written {} + {}]", c.display(), j.display());
    }
    Emitted { csv, json }
}

/// A free-form note printed alongside a report (assumptions, paper refs).
pub fn note(text: &str) {
    println!("    {text}");
}

#[cfg(test)]
mod tests {
    use super::*;

    // Both tests rebind ASTIR_RESULTS; serialize them so the parallel test
    // runner cannot interleave the set/remove pairs.
    static ENV_LOCK: crate::sync::Mutex<()> = crate::sync::Mutex::new(());

    #[test]
    fn emit_writes_csv_and_json() {
        let _guard = ENV_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        let dir = std::env::temp_dir().join("astir_report_test");
        std::env::set_var("ASTIR_RESULTS", &dir);
        let mut t = Table::new(&["a", "b"]);
        t.push_row(vec![1.0, 2.0]);
        let out = emit("unit_test_table", "unit test", &t);
        std::env::remove_var("ASTIR_RESULTS");
        let csv = out.csv.expect("csv written");
        let json = out.json.expect("json written");
        assert!(csv.exists() && json.exists());
        assert!(std::fs::read_to_string(&csv).unwrap().contains("a,b"));
        assert!(std::fs::read_to_string(&json).unwrap().starts_with("{\"columns\":"));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn emit_degrades_on_unwritable_dir() {
        let _guard = ENV_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        // Point the results dir *under a regular file* so create_dir_all
        // fails deterministically, on any platform, even as root.
        let blocker = std::env::temp_dir().join("astir_report_blocker");
        std::fs::write(&blocker, b"x").unwrap();
        let dir = blocker.join("sub");
        std::env::set_var("ASTIR_RESULTS", &dir);
        let mut t = Table::new(&["a"]);
        t.push_row(vec![1.0]);
        let out = emit("unwritable_table", "unwritable", &t);
        std::env::remove_var("ASTIR_RESULTS");
        assert!(out.csv.is_none() && out.json.is_none());
        let _ = std::fs::remove_file(&blocker);
    }
}
