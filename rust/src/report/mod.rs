//! Report emission: every experiment driver funnels its table through
//! [`emit`], which prints the aligned text (what the paper's figure shows)
//! and persists the CSV under `results/` so the series can be re-plotted.

use std::path::PathBuf;

use crate::metrics::Table;

/// Directory for CSV outputs: `$ASTIR_RESULTS` or `./results`.
pub fn results_dir() -> PathBuf {
    std::env::var_os("ASTIR_RESULTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("results"))
}

/// Print a titled, aligned table and write `results/<name>.csv`.
/// Returns the CSV path (best-effort: IO errors are reported, not fatal —
/// benches still print their numbers on read-only filesystems).
pub fn emit(name: &str, title: &str, table: &Table) -> Option<PathBuf> {
    println!("\n--- {title} ---");
    print!("{}", table.to_aligned());
    let path = results_dir().join(format!("{name}.csv"));
    match table.write_csv(&path) {
        Ok(()) => {
            println!("[written {}]", path.display());
            Some(path)
        }
        Err(e) => {
            eprintln!("[warn] could not write {}: {e}", path.display());
            None
        }
    }
}

/// A free-form note printed alongside a report (assumptions, paper refs).
pub fn note(text: &str) {
    println!("    {text}");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn emit_writes_csv() {
        let dir = std::env::temp_dir().join("astir_report_test");
        std::env::set_var("ASTIR_RESULTS", &dir);
        let mut t = Table::new(&["a", "b"]);
        t.push_row(vec![1.0, 2.0]);
        let p = emit("unit_test_table", "unit test", &t).unwrap();
        assert!(p.exists());
        let body = std::fs::read_to_string(&p).unwrap();
        assert!(body.contains("a,b"));
        std::env::remove_var("ASTIR_RESULTS");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
