//! Crate-local error type — the stand-in for `anyhow` in this offline,
//! zero-dependency build.
//!
//! The surface mirrors the subset of `anyhow` the crate actually uses:
//! a string-backed [`Error`], a [`Result`] alias with a defaulted error
//! parameter, a [`Context`] extension trait for prefixing errors, and the
//! [`err!`](crate::err)/[`bail!`](crate::bail) constructor macros. Keeping
//! the same call-site shapes means the PJRT feature code (which is only
//! compiled with `--features pjrt`) did not have to change its error
//! handling when the dependency was dropped.

use std::fmt;

/// A string-backed error with optional context prefixes.
pub struct Error {
    msg: String,
}

impl Error {
    /// Build an error from anything displayable.
    pub fn msg(msg: impl fmt::Display) -> Self {
        Error { msg: msg.to_string() }
    }

    /// Prefix the error with a context line (`context: original`).
    pub fn context(self, ctx: impl fmt::Display) -> Self {
        Error { msg: format!("{ctx}: {}", self.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

// Debug mirrors Display so `fn main() -> Result<()>` prints the message,
// not a struct dump.
impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl std::error::Error for Error {}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::msg(e)
    }
}

impl From<String> for Error {
    fn from(msg: String) -> Self {
        Error { msg }
    }
}

impl From<&str> for Error {
    fn from(msg: &str) -> Self {
        Error { msg: msg.to_string() }
    }
}

/// Crate-wide result alias (error type defaults to [`Error`]).
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding context prefixes to any displayable error.
pub trait Context<T> {
    /// Wrap the error as `ctx: original`.
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T>;

    /// Like [`Context::context`], with the prefix built lazily.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{ctx}: {e}")))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{}: {e}", f())))
    }
}

/// Construct an [`Error`] from a format string (the offline `anyhow!`).
/// Like `anyhow!`, a single non-literal expression is taken as a
/// displayable message, not a format string — `err!(UNAVAILABLE)` works.
#[macro_export]
macro_rules! err {
    ($msg:literal $(,)?) => {
        $crate::error::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::error::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::error::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::err!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> Result<()> {
        bail!("boom {}", 42)
    }

    #[test]
    fn macros_build_messages() {
        let e = err!("x = {}", 7);
        assert_eq!(e.to_string(), "x = 7");
        assert_eq!(fails().unwrap_err().to_string(), "boom 42");
        // bare literal (with inline capture) and bare non-literal expression
        let n = 3;
        assert_eq!(err!("n = {n}").to_string(), "n = 3");
        const MSG: &str = "const message";
        assert_eq!(err!(MSG).to_string(), "const message");
        fn const_bail() -> Result<()> {
            bail!(MSG)
        }
        assert_eq!(const_bail().unwrap_err().to_string(), "const message");
    }

    #[test]
    fn context_prefixes() {
        let r: std::result::Result<(), String> = Err("inner".to_string());
        let e = r.context("outer").unwrap_err();
        assert_eq!(e.to_string(), "outer: inner");
        let r: std::result::Result<(), String> = Err("inner".to_string());
        let e = r.with_context(|| format!("outer {}", 2)).unwrap_err();
        assert_eq!(e.to_string(), "outer 2: inner");
    }

    #[test]
    fn io_error_converts() {
        fn read() -> Result<String> {
            Ok(std::fs::read_to_string("/nonexistent/astir/x")?)
        }
        assert!(read().is_err());
    }

    #[test]
    fn debug_matches_display() {
        let e = Error::msg("plain");
        assert_eq!(format!("{e:?}"), format!("{e}"));
    }
}
