//! PJRT runtime — loads and executes the AOT artifacts produced by
//! `make artifacts` (`python/compile/aot.py`).
//!
//! Interchange is HLO **text** (`*.hlo.txt`): jax ≥ 0.5 serializes protos
//! with 64-bit instruction ids that xla_extension 0.5.1 rejects, while the
//! text parser reassigns ids (see `/opt/xla-example/README.md`). Each
//! artifact carries a `.meta` sidecar of `key = value` lines; discovery
//! ([`ArtifactStore::discover`]) indexes those so callers ask for
//! *"the stoiht_step for (n=1000, b=15, s=20)"* rather than file names.
//!
//! [`PjrtRuntime`] compiles artifacts on the PJRT CPU client once and
//! exposes typed entry points ([`PjrtRuntime::stoiht_step`], …) that do the
//! f64↔f32 marshalling at the boundary. The handle is cheap to clone
//! (client + compiled executables are shared), but **not** `Send`: each
//! worker thread builds its own runtime (`PjRtClient` wraps a C++ pointer
//! without thread-safety guarantees in the 0.1.6 crate).

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use crate::error::{Context, Result};
use crate::{bail, err};

/// Artifact kinds emitted by `python/compile/aot.py`.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ArtifactKind {
    /// Full Alg.-2 step: `(A_b, y_b, x, alpha, tally_mask) -> (x_next, gamma_mask)`.
    StoihtStep,
    /// Classical IHT step: `(A, y, x, gamma) -> (x_next,)`.
    IhtStep,
    /// Halting statistic: `(A, y, x) -> (||y - A x||,)`.
    Residual,
}

impl ArtifactKind {
    fn parse(s: &str) -> Option<Self> {
        match s {
            "stoiht_step" => Some(ArtifactKind::StoihtStep),
            "iht_step" => Some(ArtifactKind::IhtStep),
            "residual" => Some(ArtifactKind::Residual),
            _ => None,
        }
    }
}

/// Parsed `.meta` sidecar.
#[derive(Clone, Debug)]
pub struct ArtifactMeta {
    pub kind: ArtifactKind,
    pub n: usize,
    pub m: usize,
    /// Row count of the step input (`b` for stoiht_step, `m` otherwise).
    pub b: usize,
    pub s: usize,
    /// Path of the HLO text file.
    pub hlo_path: PathBuf,
}

/// Key under which artifacts are indexed: (kind, n, rows, s).
pub type ArtifactKey = (ArtifactKind, usize, usize, usize);

impl ArtifactMeta {
    pub fn key(&self) -> ArtifactKey {
        (self.kind, self.n, self.b, self.s)
    }

    /// Parse a sidecar file (`key = value` lines).
    pub fn from_sidecar(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        let mut kv: HashMap<String, String> = HashMap::new();
        for line in text.lines() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .ok_or_else(|| err!("bad meta line `{line}` in {}", path.display()))?;
            kv.insert(k.trim().to_string(), v.trim().to_string());
        }
        let get = |k: &str| -> Result<&String> {
            kv.get(k).ok_or_else(|| err!("meta {} missing `{k}`", path.display()))
        };
        let kind = ArtifactKind::parse(get("kind")?)
            .ok_or_else(|| err!("unknown artifact kind `{}`", kv["kind"]))?;
        let parse_usize =
            |k: &str| -> Result<usize> { Ok(get(k)?.parse::<usize>().context(k.to_string())?) };
        let hlo_path = path.with_extension("hlo.txt");
        if !hlo_path.exists() {
            bail!("HLO file {} missing for sidecar {}", hlo_path.display(), path.display());
        }
        Ok(ArtifactMeta {
            kind,
            n: parse_usize("n")?,
            m: parse_usize("m")?,
            b: parse_usize("b")?,
            s: parse_usize("s")?,
            hlo_path,
        })
    }
}

/// Index of all artifacts under a directory.
#[derive(Clone, Debug, Default)]
pub struct ArtifactStore {
    artifacts: HashMap<ArtifactKey, ArtifactMeta>,
    pub dir: PathBuf,
}

impl ArtifactStore {
    /// Scan `dir` for `*.meta` sidecars.
    pub fn discover(dir: &Path) -> Result<Self> {
        let mut artifacts = HashMap::new();
        let entries = std::fs::read_dir(dir)
            .with_context(|| format!("artifact dir {} (run `make artifacts`)", dir.display()))?;
        for entry in entries {
            let path = entry?.path();
            if path.extension().and_then(|e| e.to_str()) == Some("meta") {
                let meta = ArtifactMeta::from_sidecar(&path)?;
                artifacts.insert(meta.key(), meta);
            }
        }
        Ok(ArtifactStore { artifacts, dir: dir.to_path_buf() })
    }

    /// Default directory: `$ASTIR_ARTIFACTS`, else `./artifacts`, else
    /// `<crate root>/artifacts` (so examples work from any cwd).
    pub fn default_dir() -> PathBuf {
        if let Some(dir) = std::env::var_os("ASTIR_ARTIFACTS") {
            return PathBuf::from(dir);
        }
        let local = PathBuf::from("artifacts");
        if local.is_dir() {
            return local;
        }
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    pub fn len(&self) -> usize {
        self.artifacts.len()
    }

    pub fn is_empty(&self) -> bool {
        self.artifacts.is_empty()
    }

    pub fn get(&self, key: &ArtifactKey) -> Option<&ArtifactMeta> {
        self.artifacts.get(key)
    }

    pub fn iter(&self) -> impl Iterator<Item = &ArtifactMeta> {
        self.artifacts.values()
    }
}

#[cfg(feature = "pjrt")]
pub use pjrt_impl::PjrtRuntime;
#[cfg(not(feature = "pjrt"))]
pub use pjrt_stub::PjrtRuntime;

/// Real PJRT-backed runtime — compiled only with the off-by-default `pjrt`
/// feature, which additionally requires the `xla` bindings crate (see the
/// feature note in `rust/Cargo.toml` and README.md). The plain build links
/// no XLA symbols and stays hermetic.
#[cfg(feature = "pjrt")]
mod pjrt_impl {
    use std::collections::HashMap;
    use std::path::Path;

    use crate::error::Result;
    use crate::{bail, err};

    use super::{ArtifactKey, ArtifactKind, ArtifactStore};

    /// A compiled-executable cache over an [`ArtifactStore`] on the PJRT CPU
    /// client. Not `Send` — build one per thread.
    pub struct PjrtRuntime {
        client: xla::PjRtClient,
        store: ArtifactStore,
        compiled: std::cell::RefCell<HashMap<ArtifactKey, std::rc::Rc<xla::PjRtLoadedExecutable>>>,
    }

    impl PjrtRuntime {
        /// CPU client over the given artifact directory.
        pub fn new(dir: &Path) -> Result<Self> {
            let store = ArtifactStore::discover(dir)?;
            if store.is_empty() {
                bail!("no artifacts found in {} (run `make artifacts`)", dir.display());
            }
            let client = xla::PjRtClient::cpu().map_err(|e| err!("PJRT CPU client: {e:?}"))?;
            Ok(PjrtRuntime { client, store, compiled: Default::default() })
        }

        /// Runtime over [`ArtifactStore::default_dir`].
        pub fn from_default_dir() -> Result<Self> {
            Self::new(&ArtifactStore::default_dir())
        }

        pub fn store(&self) -> &ArtifactStore {
            &self.store
        }

        /// PJRT platform name (diagnostics).
        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        /// Load + compile (memoized) the artifact for `key`.
        fn executable(&self, key: ArtifactKey) -> Result<std::rc::Rc<xla::PjRtLoadedExecutable>> {
            if let Some(exe) = self.compiled.borrow().get(&key) {
                return Ok(exe.clone());
            }
            let meta = self
                .store
                .get(&key)
                .ok_or_else(|| err!("no artifact for {key:?} in {}", self.store.dir.display()))?;
            let path_str = meta
                .hlo_path
                .to_str()
                .ok_or_else(|| err!("non-UTF8 path {}", meta.hlo_path.display()))?;
            let proto = xla::HloModuleProto::from_text_file(path_str)
                .map_err(|e| err!("parsing {}: {e:?}", meta.hlo_path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| err!("compiling {}: {e:?}", meta.hlo_path.display()))?;
            let exe = std::rc::Rc::new(exe);
            self.compiled.borrow_mut().insert(key, exe.clone());
            Ok(exe)
        }

        /// Execute one Alg.-2 step on the artifact for `(n, b, s)`.
        ///
        /// Marshals f64 slices to the artifact's f32 and back.
        /// Returns `(x_next, gamma_mask_indices)` with the gamma mask already
        /// converted to sorted indices.
        #[allow(clippy::too_many_arguments)]
        pub fn stoiht_step(
            &self,
            n: usize,
            b: usize,
            s: usize,
            a_blk: &[f64],
            y_blk: &[f64],
            x: &[f64],
            alpha: f64,
            tally_mask: &[f64],
        ) -> Result<(Vec<f64>, Vec<usize>)> {
            assert_eq!(a_blk.len(), b * n);
            assert_eq!(y_blk.len(), b);
            assert_eq!(x.len(), n);
            assert_eq!(tally_mask.len(), n);
            let exe = self.executable((ArtifactKind::StoihtStep, n, b, s))?;
            let a_lit = lit_mat(a_blk, b, n)?;
            let y_lit = lit_vec(y_blk);
            let x_lit = lit_vec(x);
            let alpha_lit = xla::Literal::scalar(alpha as f32);
            let mask_lit = lit_vec(tally_mask);
            let result = exe
                .execute::<xla::Literal>(&[a_lit, y_lit, x_lit, alpha_lit, mask_lit])
                .map_err(|e| err!("execute stoiht_step: {e:?}"))?[0][0]
                .to_literal_sync()
                .map_err(|e| err!("fetch result: {e:?}"))?;
            let mut parts = result.to_tuple().map_err(|e| err!("untuple: {e:?}"))?;
            if parts.len() != 2 {
                bail!("stoiht_step artifact returned {} outputs, want 2", parts.len());
            }
            let gamma_lit = parts.pop().unwrap();
            let x_lit = parts.pop().unwrap();
            let x_next: Vec<f64> = to_f64(&x_lit)?;
            let gamma_mask: Vec<f64> = to_f64(&gamma_lit)?;
            let gamma: Vec<usize> = (0..n).filter(|&i| gamma_mask[i] != 0.0).collect();
            Ok((x_next, gamma))
        }

        /// Execute one classical IHT step on the artifact for `(n, m, s)`.
        #[allow(clippy::too_many_arguments)]
        pub fn iht_step(
            &self,
            n: usize,
            m: usize,
            s: usize,
            a: &[f64],
            y: &[f64],
            x: &[f64],
            gamma: f64,
        ) -> Result<Vec<f64>> {
            let exe = self.executable((ArtifactKind::IhtStep, n, m, s))?;
            let a_lit = lit_mat(a, m, n)?;
            let y_lit = lit_vec(y);
            let x_lit = lit_vec(x);
            let g_lit = xla::Literal::scalar(gamma as f32);
            let result = exe
                .execute::<xla::Literal>(&[a_lit, y_lit, x_lit, g_lit])
                .map_err(|e| err!("execute iht_step: {e:?}"))?[0][0]
                .to_literal_sync()
                .map_err(|e| err!("fetch result: {e:?}"))?;
            let out = result.to_tuple1().map_err(|e| err!("untuple: {e:?}"))?;
            to_f64(&out)
        }

        /// Execute the residual-norm artifact for `(n, m)`.
        pub fn residual_norm(
            &self,
            n: usize,
            m: usize,
            a: &[f64],
            y: &[f64],
            x: &[f64],
        ) -> Result<f64> {
            // residual artifacts are keyed with rows = m, s = m (see aot.py meta).
            let key = self
                .store
                .iter()
                .find(|meta| meta.kind == ArtifactKind::Residual && meta.n == n && meta.m == m)
                .map(|meta| meta.key())
                .ok_or_else(|| err!("no residual artifact for n={n} m={m}"))?;
            let exe = self.executable(key)?;
            let a_lit = lit_mat(a, m, n)?;
            let y_lit = lit_vec(y);
            let x_lit = lit_vec(x);
            let result = exe
                .execute::<xla::Literal>(&[a_lit, y_lit, x_lit])
                .map_err(|e| err!("execute residual: {e:?}"))?[0][0]
                .to_literal_sync()
                .map_err(|e| err!("fetch result: {e:?}"))?;
            let out = result.to_tuple1().map_err(|e| err!("untuple: {e:?}"))?;
            let v = out
                .get_first_element::<f32>()
                .map_err(|e| err!("scalar fetch: {e:?}"))?;
            Ok(v as f64)
        }
    }

    fn lit_vec(v: &[f64]) -> xla::Literal {
        let f: Vec<f32> = v.iter().map(|&x| x as f32).collect();
        xla::Literal::vec1(&f)
    }

    fn lit_mat(v: &[f64], rows: usize, cols: usize) -> Result<xla::Literal> {
        let f: Vec<f32> = v.iter().map(|&x| x as f32).collect();
        xla::Literal::vec1(&f)
            .reshape(&[rows as i64, cols as i64])
            .map_err(|e| err!("reshape ({rows},{cols}): {e:?}"))
    }

    fn to_f64(lit: &xla::Literal) -> Result<Vec<f64>> {
        let v: Vec<f32> = lit.to_vec().map_err(|e| err!("literal to_vec: {e:?}"))?;
        Ok(v.into_iter().map(|x| x as f64).collect())
    }
}

/// Stub runtime compiled when the `pjrt` feature is **off** (the default):
/// keeps every call site — `backend::PjrtBackend`, the CLI, the benches —
/// type-checking without linking any XLA symbol. Construction fails with an
/// actionable error, so a hermetic `cargo build && cargo test` never hits
/// the missing runtime.
#[cfg(not(feature = "pjrt"))]
mod pjrt_stub {
    use std::path::Path;

    use crate::bail;
    use crate::error::Result;

    use super::ArtifactStore;

    const UNAVAILABLE: &str =
        "PJRT support is not compiled in: rebuild with `--features pjrt` \
         (requires the `xla` bindings crate; see README.md)";

    /// Placeholder with the same API surface as the real `PjrtRuntime`.
    pub struct PjrtRuntime {
        store: ArtifactStore,
    }

    impl PjrtRuntime {
        pub fn new(_dir: &Path) -> Result<Self> {
            bail!(UNAVAILABLE)
        }

        pub fn from_default_dir() -> Result<Self> {
            Self::new(&ArtifactStore::default_dir())
        }

        pub fn store(&self) -> &ArtifactStore {
            &self.store
        }

        pub fn platform(&self) -> String {
            String::from("unavailable (built without the `pjrt` feature)")
        }

        #[allow(clippy::too_many_arguments)]
        pub fn stoiht_step(
            &self,
            _n: usize,
            _b: usize,
            _s: usize,
            _a_blk: &[f64],
            _y_blk: &[f64],
            _x: &[f64],
            _alpha: f64,
            _tally_mask: &[f64],
        ) -> Result<(Vec<f64>, Vec<usize>)> {
            bail!(UNAVAILABLE)
        }

        #[allow(clippy::too_many_arguments)]
        pub fn iht_step(
            &self,
            _n: usize,
            _m: usize,
            _s: usize,
            _a: &[f64],
            _y: &[f64],
            _x: &[f64],
            _gamma: f64,
        ) -> Result<Vec<f64>> {
            bail!(UNAVAILABLE)
        }

        pub fn residual_norm(
            &self,
            _n: usize,
            _m: usize,
            _a: &[f64],
            _y: &[f64],
            _x: &[f64],
        ) -> Result<f64> {
            bail!(UNAVAILABLE)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> Option<PathBuf> {
        // Tests run from the crate root; skip when artifacts are not built.
        let dir = ArtifactStore::default_dir();
        if dir.join("stoiht_step_n32_b4_s3.meta").exists() {
            Some(dir)
        } else {
            eprintln!("skipping PJRT test: artifacts not built (run `make artifacts`)");
            None
        }
    }

    #[test]
    fn sidecar_parsing_roundtrip() {
        let Some(dir) = artifacts_dir() else { return };
        let meta = ArtifactMeta::from_sidecar(&dir.join("stoiht_step_n32_b4_s3.meta")).unwrap();
        assert_eq!(meta.kind, ArtifactKind::StoihtStep);
        assert_eq!((meta.n, meta.m, meta.b, meta.s), (32, 16, 4, 3));
        assert!(meta.hlo_path.exists());
    }

    #[test]
    fn discovery_finds_default_set() {
        let Some(dir) = artifacts_dir() else { return };
        let store = ArtifactStore::discover(&dir).unwrap();
        // 2 shapes x 3 kinds
        assert!(store.len() >= 6, "found {}", store.len());
        assert!(store.get(&(ArtifactKind::StoihtStep, 1000, 15, 20)).is_some());
        assert!(store.get(&(ArtifactKind::IhtStep, 32, 16, 3)).is_some());
    }

    #[test]
    fn missing_dir_errors() {
        assert!(ArtifactStore::discover(Path::new("/nonexistent/astir")).is_err());
    }

    #[test]
    fn bad_sidecar_errors() {
        let dir = std::env::temp_dir().join("astir_meta_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("x.meta");
        std::fs::write(&p, "kind = stoiht_step\nn = 4\n").unwrap();
        // missing keys + missing HLO file
        assert!(ArtifactMeta::from_sidecar(&p).is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
