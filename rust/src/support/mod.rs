//! Support identification — the `supp_s(·)` operator of the paper and the
//! set plumbing around it.
//!
//! `supp_s(a)` returns the indices of the `s` largest-magnitude entries of
//! `a`. It runs on every iteration of every algorithm here, so the
//! implementation is an allocation-free (given a scratch buffer) quickselect
//! over indices with **deterministic tie-breaking toward the lower index**,
//! matching `jax.lax.top_k` so the native backend and the AOT artifacts
//! agree bit-for-bit on supports.

use crate::linalg::Scalar;

/// Ordering used everywhere: entry `i` beats entry `j` iff
/// `|v[i]| > |v[j]|`, or the magnitudes are equal and `i < j`.
#[inline(always)]
fn beats<S: Scalar>(v: &[S], i: usize, j: usize) -> bool {
    let (ai, aj) = (v[i].abs(), v[j].abs());
    if ai != aj {
        ai > aj
    } else {
        i < j
    }
}

/// Indices of the `s` largest-|·| entries of `v`, **sorted ascending**.
///
/// Allocates two scratch vectors; use [`top_s_into`] in hot loops.
pub fn top_s<S: Scalar>(v: &[S], s: usize) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..v.len()).collect();
    let mut out = vec![0usize; s.min(v.len())];
    top_s_into(v, s, &mut idx, &mut out);
    out
}

/// Allocation-free top-`s`: `idx` must be a scratch of length `v.len()`
/// (contents ignored), `out` of length `min(s, v.len())`. `out` is filled
/// with the selected indices, sorted ascending.
pub fn top_s_into<S: Scalar>(v: &[S], s: usize, idx: &mut Vec<usize>, out: &mut [usize]) {
    let n = v.len();
    let s = s.min(n);
    assert_eq!(out.len(), s, "top_s_into: out length");
    idx.clear();
    idx.extend(0..n);
    if s > 0 && s < n {
        quickselect(v, idx, s);
    }
    out.copy_from_slice(&idx[..s]);
    out.sort_unstable();
}

/// Partition `idx` so its first `s` entries are the top-`s` under [`beats`].
fn quickselect<S: Scalar>(v: &[S], idx: &mut [usize], s: usize) {
    let mut lo = 0usize;
    let mut hi = idx.len();
    let mut want = s;
    // Deterministic pseudo-random pivot stream (decouples worst cases from
    // adversarial input order without RNG plumbing).
    let mut pstate = 0x9E3779B97F4A7C15u64 ^ (idx.len() as u64);
    while hi - lo > 1 {
        if want >= hi - lo {
            // The remaining range is selected wholesale — partitioning it
            // further would only shuffle already-chosen entries.
            return;
        }
        pstate ^= pstate << 13;
        pstate ^= pstate >> 7;
        pstate ^= pstate << 17;
        let pivot_at = lo + (pstate % (hi - lo) as u64) as usize;
        idx.swap(lo, pivot_at);
        let pivot = idx[lo];
        // Hoare-style partition on `beats(pivot)`.
        let mut i = lo + 1;
        let mut j = hi - 1;
        loop {
            while i <= j && beats(v, idx[i], pivot) {
                i += 1;
            }
            while i <= j && !beats(v, idx[j], pivot) {
                if j == 0 {
                    break;
                }
                j -= 1;
            }
            if i >= j {
                break;
            }
            idx.swap(i, j);
            i += 1;
            j -= 1;
        }
        let pivot_pos = i - 1;
        idx.swap(lo, pivot_pos);
        let rank = pivot_pos - lo + 1; // # of elements in [lo, pivot_pos]
        if want == rank {
            return; // the pivot closes the boundary exactly
        }
        if want < rank {
            hi = pivot_pos;
        } else {
            want -= rank;
            lo = pivot_pos + 1;
        }
    }
}

/// 0/1 mask of the top-`s` entries (same dtype as `v`).
pub fn top_s_mask<S: Scalar>(v: &[S], s: usize) -> Vec<S> {
    let mut mask = vec![S::ZERO; v.len()];
    for i in top_s(v, s) {
        mask[i] = S::ONE;
    }
    mask
}

/// Hard-thresholding operator `H_s` (paper eq. (2)): zero all but the
/// top-`s` entries, in place.
pub fn hard_threshold_in_place<S: Scalar>(
    v: &mut [S],
    s: usize,
    idx_scratch: &mut Vec<usize>,
    sel_scratch: &mut [usize],
) {
    top_s_into(v, s, idx_scratch, sel_scratch);
    let mut keep = 0usize;
    // sel_scratch is ascending: zero everything not in it with one pass.
    for i in 0..v.len() {
        if keep < sel_scratch.len() && sel_scratch[keep] == i {
            keep += 1;
        } else {
            v[i] = S::ZERO;
        }
    }
}

/// Project `v` onto an index set: zero everything outside `keep`
/// (`keep` must be sorted ascending).
pub fn project_onto<S: Scalar>(v: &mut [S], keep: &[usize]) {
    debug_assert!(keep.windows(2).all(|w| w[0] < w[1]), "keep must be sorted");
    let mut k = 0usize;
    for i in 0..v.len() {
        if k < keep.len() && keep[k] == i {
            k += 1;
        } else {
            v[i] = S::ZERO;
        }
    }
}

/// Sorted union of two ascending index sets, written into a caller buffer
/// (cleared first) — the allocation-free form the hot loops use.
pub fn union_into(a: &[usize], b: &[usize], out: &mut Vec<usize>) {
    out.clear();
    out.reserve(a.len() + b.len());
    let (mut i, mut j) = (0usize, 0usize);
    while i < a.len() && j < b.len() {
        match a[i].cmp(&b[j]) {
            std::cmp::Ordering::Less => {
                out.push(a[i]);
                i += 1;
            }
            std::cmp::Ordering::Greater => {
                out.push(b[j]);
                j += 1;
            }
            std::cmp::Ordering::Equal => {
                out.push(a[i]);
                i += 1;
                j += 1;
            }
        }
    }
    out.extend_from_slice(&a[i..]);
    out.extend_from_slice(&b[j..]);
}

/// Sorted union of two ascending index sets.
pub fn union(a: &[usize], b: &[usize]) -> Vec<usize> {
    let mut out = Vec::new();
    union_into(a, b, &mut out);
    out
}

/// Size of the intersection of two ascending index sets.
pub fn intersection_size(a: &[usize], b: &[usize]) -> usize {
    let (mut i, mut j, mut k) = (0usize, 0usize, 0usize);
    while i < a.len() && j < b.len() {
        match a[i].cmp(&b[j]) {
            std::cmp::Ordering::Less => i += 1,
            std::cmp::Ordering::Greater => j += 1,
            std::cmp::Ordering::Equal => {
                k += 1;
                i += 1;
                j += 1;
            }
        }
    }
    k
}

/// Support-estimate accuracy `|T̃ ∩ T| / |T̃|` (the paper's `α`, Fig. 1).
pub fn accuracy(estimate: &[usize], truth: &[usize]) -> f64 {
    if estimate.is_empty() {
        return 0.0;
    }
    intersection_size(estimate, truth) as f64 / estimate.len() as f64
}

/// The (sorted) support of a vector: indices with nonzero entries.
pub fn support_of<S: Scalar>(v: &[S]) -> Vec<usize> {
    (0..v.len()).filter(|&i| v[i] != S::ZERO).collect()
}

/// Build a support estimate of size `s` with exact accuracy `α = hits/s`
/// against `truth` (Fig. 1's oracle T̃): take `hits` true indices and
/// `s - hits` indices outside the truth, both chosen at random.
pub fn oracle_estimate(
    truth: &[usize],
    n: usize,
    s: usize,
    hits: usize,
    rng: &mut crate::rng::Rng,
) -> Vec<usize> {
    assert!(hits <= s && hits <= truth.len());
    let mut est: Vec<usize> = {
        let picked = rng.subset(truth.len(), hits);
        picked.into_iter().map(|k| truth[k]).collect()
    };
    let truth_set: std::collections::HashSet<usize> = truth.iter().copied().collect();
    let complement: Vec<usize> = (0..n).filter(|i| !truth_set.contains(i)).collect();
    let extra = rng.subset(complement.len(), s - hits);
    est.extend(extra.into_iter().map(|k| complement[k]));
    est.sort_unstable();
    est
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    /// Reference top-s by full sort (the oracle the quickselect must match).
    fn top_s_ref(v: &[f64], s: usize) -> Vec<usize> {
        let mut idx: Vec<usize> = (0..v.len()).collect();
        idx.sort_by(|&i, &j| {
            v[j].abs()
                .partial_cmp(&v[i].abs())
                .unwrap()
                .then(i.cmp(&j))
        });
        let mut out = idx[..s.min(v.len())].to_vec();
        out.sort_unstable();
        out
    }

    #[test]
    fn matches_sort_reference_randomized() {
        let mut rng = Rng::seed_from(2024);
        for trial in 0..300 {
            let n = 1 + rng.below(200);
            let s = rng.below(n + 1);
            let v: Vec<f64> = (0..n).map(|_| rng.gauss()).collect();
            assert_eq!(top_s(&v, s), top_s_ref(&v, s), "trial {trial} n={n} s={s}");
        }
    }

    #[test]
    fn handles_ties_deterministically() {
        // all equal magnitudes -> lowest indices win
        let v = vec![1.0f64; 10];
        assert_eq!(top_s(&v, 3), vec![0, 1, 2]);
        // equal |.| with mixed signs
        let v = vec![-2.0, 2.0, -2.0, 1.0];
        assert_eq!(top_s(&v, 2), vec![0, 1]);
    }

    #[test]
    fn degenerate_sizes() {
        let v = vec![3.0f64, -1.0, 2.0];
        assert_eq!(top_s(&v, 0), Vec::<usize>::new());
        assert_eq!(top_s(&v, 3), vec![0, 1, 2]);
        assert_eq!(top_s(&v, 10), vec![0, 1, 2]); // s > n clamps
        let empty: Vec<f64> = vec![];
        assert_eq!(top_s(&empty, 5), Vec::<usize>::new());
    }

    #[test]
    fn mask_and_threshold_consistent() {
        let mut rng = Rng::seed_from(77);
        let v: Vec<f64> = (0..50).map(|_| rng.gauss()).collect();
        let mask = top_s_mask(&v, 7);
        assert_eq!(mask.iter().filter(|&&m| m == 1.0).count(), 7);
        let mut w = v.clone();
        let mut scratch = Vec::new();
        let mut sel = vec![0usize; 7];
        hard_threshold_in_place(&mut w, 7, &mut scratch, &mut sel);
        for i in 0..50 {
            if mask[i] == 1.0 {
                assert_eq!(w[i], v[i]);
            } else {
                assert_eq!(w[i], 0.0);
            }
        }
    }

    #[test]
    fn project_keeps_only_listed() {
        let mut v = vec![1.0f64, 2.0, 3.0, 4.0, 5.0];
        project_onto(&mut v, &[1, 3]);
        assert_eq!(v, vec![0.0, 2.0, 0.0, 4.0, 0.0]);
    }

    #[test]
    fn union_and_intersection() {
        assert_eq!(union(&[1, 3, 5], &[2, 3, 6]), vec![1, 2, 3, 5, 6]);
        assert_eq!(union(&[], &[2]), vec![2]);
        assert_eq!(union(&[], &[]), Vec::<usize>::new());
        assert_eq!(intersection_size(&[1, 3, 5], &[3, 5, 9]), 2);
        assert_eq!(intersection_size(&[], &[1]), 0);
    }

    #[test]
    fn union_into_reuses_buffer() {
        let mut buf = vec![99usize; 3]; // stale contents must be discarded
        union_into(&[0, 4], &[2, 4, 7], &mut buf);
        assert_eq!(buf, vec![0, 2, 4, 7]);
        union_into(&[], &[], &mut buf);
        assert!(buf.is_empty());
    }

    #[test]
    fn quickselect_fully_selected_ranges() {
        // Exercise the early-return paths: want equal to the live range and
        // want == rank - 1 (pivot lands just past the boundary).
        let mut rng = Rng::seed_from(404);
        for _ in 0..200 {
            let n = 2 + rng.below(64);
            let s = 1 + rng.below(n - 1);
            let v: Vec<f64> = (0..n).map(|_| rng.gauss()).collect();
            assert_eq!(top_s(&v, s), top_s_ref(&v, s));
        }
        // Many ties force rank boundaries of every flavour.
        let v = vec![1.0f64; 17];
        for s in 1..17 {
            assert_eq!(top_s(&v, s), (0..s).collect::<Vec<_>>());
        }
    }

    #[test]
    fn accuracy_matches_definition() {
        assert_eq!(accuracy(&[1, 2, 3, 4], &[2, 4, 9]), 0.5);
        assert_eq!(accuracy(&[], &[1]), 0.0);
        assert_eq!(accuracy(&[1, 2], &[1, 2]), 1.0);
    }

    #[test]
    fn oracle_estimate_has_exact_accuracy() {
        let mut rng = Rng::seed_from(5);
        let truth: Vec<usize> = vec![3, 10, 25, 40, 77];
        for hits in 0..=5usize {
            let est = oracle_estimate(&truth, 100, 5, hits, &mut rng);
            assert_eq!(est.len(), 5);
            assert_eq!(intersection_size(&est, &truth), hits);
            assert!(est.windows(2).all(|w| w[0] < w[1]));
        }
    }

    #[test]
    fn support_of_finds_nonzeros() {
        assert_eq!(support_of(&[0.0f64, 1.0, 0.0, -2.0]), vec![1, 3]);
        assert_eq!(support_of::<f64>(&[]), Vec::<usize>::new());
    }

    #[test]
    fn top_s_into_no_alloc_path() {
        let v: Vec<f64> = vec![5.0, -9.0, 1.0, 7.0];
        let mut scratch = Vec::new();
        let mut out = vec![0usize; 2];
        top_s_into(&v, 2, &mut scratch, &mut out);
        assert_eq!(out, vec![1, 3]);
    }
}
