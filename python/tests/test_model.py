"""Layer-2 correctness: full step graphs vs the oracle, and support-logic
invariants (top-s cardinality, union semantics, Alg.1/Alg.2 equivalence at
zero tally)."""

from __future__ import annotations

import numpy as np
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile import model
from compile.kernels import ref

F32 = np.float32


def _problem(rng, b, n):
    a = (rng.standard_normal((b, n)) / np.sqrt(b)).astype(F32)
    y = rng.standard_normal((b,)).astype(F32)
    x = rng.standard_normal((n,)).astype(F32)
    return a, y, x


@settings(max_examples=30, deadline=None)
@given(
    b=st.integers(1, 12),
    n=st.integers(4, 120),
    s_frac=st.floats(0.05, 0.9),
    seed=st.integers(0, 2**31 - 1),
)
def test_stoiht_step_matches_ref(b, n, s_frac, seed):
    rng = np.random.default_rng(seed)
    s = max(1, int(n * s_frac))
    a, y, x = _problem(rng, b, n)
    tally = (rng.random(n) < 0.1).astype(F32)
    got_x, got_g = model.stoiht_step(a, y, x, F32(0.9), tally, s=s)
    want_x, want_g = ref.stoiht_step_ref(a, y, x, F32(0.9), tally, s)
    np.testing.assert_allclose(np.asarray(got_x), np.asarray(want_x), rtol=3e-5, atol=3e-5)
    np.testing.assert_array_equal(np.asarray(got_g), np.asarray(want_g))


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), s=st.integers(1, 20))
def test_gamma_mask_cardinality(seed, s):
    rng = np.random.default_rng(seed)
    a, y, x = _problem(rng, 8, 64)
    _, g = model.stoiht_step(a, y, x, F32(1.0), np.zeros(64, F32), s=s)
    g = np.asarray(g)
    assert set(np.unique(g)) <= {0.0, 1.0}
    assert int(g.sum()) == s


def test_zero_tally_equals_alg1():
    """Alg. 2 with an empty tally estimate reduces exactly to Alg. 1."""
    rng = np.random.default_rng(7)
    a, y, x = _problem(rng, 6, 50)
    s = 5
    x2, g = model.stoiht_step(a, y, x, F32(1.0), np.zeros(50, F32), s=s)
    b = ref.block_grad_ref(a, y, x, F32(1.0))
    alg1 = ref.hard_threshold_ref(b, s)
    np.testing.assert_allclose(np.asarray(x2), np.asarray(alg1), rtol=1e-5, atol=1e-5)
    # With zero tally the support of x_next is exactly Gamma^t.
    assert int(np.count_nonzero(np.asarray(x2))) <= s


def test_estimate_support_is_union():
    """supp(x_next) ⊆ Gamma^t ∪ supp(tally_mask), and covers tally entries
    where b is nonzero."""
    rng = np.random.default_rng(11)
    a, y, x = _problem(rng, 6, 50)
    s = 5
    tally = np.zeros(50, F32)
    tally_idx = [3, 17, 42]
    tally[tally_idx] = 1.0
    x2, g = model.stoiht_step(a, y, x, F32(1.0), tally, s=s)
    x2, g = np.asarray(x2), np.asarray(g)
    union = np.maximum(g, tally)
    assert np.all((x2 != 0) <= (union > 0))
    b = np.asarray(ref.block_grad_ref(a, y, x, F32(1.0)))
    for i in tally_idx:
        np.testing.assert_allclose(x2[i], b[i], rtol=1e-5)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_residual_norm_matches_ref(seed):
    rng = np.random.default_rng(seed)
    m, n = 24, 60
    a = rng.standard_normal((m, n)).astype(F32)
    y = rng.standard_normal((m,)).astype(F32)
    x = rng.standard_normal((n,)).astype(F32)
    got = float(model.residual_norm(a, y, x))
    want = float(ref.residual_norm_ref(a, y, x))
    np.testing.assert_allclose(got, want, rtol=1e-5)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), s=st.integers(1, 10))
def test_iht_step_matches_ref(seed, s):
    rng = np.random.default_rng(seed)
    m, n = 20, 64
    a = (rng.standard_normal((m, n)) / np.sqrt(m)).astype(F32)
    y = rng.standard_normal((m,)).astype(F32)
    x = rng.standard_normal((n,)).astype(F32)
    got = np.asarray(model.iht_step(a, y, x, F32(0.8), s=s))
    want = np.asarray(ref.iht_step_ref(a, y, x, F32(0.8), s))
    np.testing.assert_allclose(got, want, rtol=3e-5, atol=3e-5)
    assert int(np.count_nonzero(got)) <= s


def test_stoiht_converges_on_easy_problem():
    """Pure-oracle sanity: Alg. 1 solves an easy compressed-sensing instance.

    This pins the *algorithm semantics* (step weight gamma/(M p), uniform
    block sampling, top-s projection) that the Rust port must reproduce.
    """
    rng = np.random.default_rng(42)
    n, m, b, s = 128, 64, 8, 4
    M = m // b
    a = (rng.standard_normal((m, n)) / np.sqrt(m)).astype(F32)
    xt = np.zeros(n, F32)
    supp = rng.choice(n, s, replace=False)
    xt[supp] = rng.standard_normal(s).astype(F32)
    y = a @ xt
    x = np.zeros(n, F32)
    for t in range(400):
        i = rng.integers(M)
        ab, yb = a[i * b : (i + 1) * b], y[i * b : (i + 1) * b]
        bvec = ref.block_grad_ref(ab, yb, x, F32(1.0))  # gamma/(M p) = 1*M/M
        x = np.asarray(ref.hard_threshold_ref(bvec, s))
        if np.linalg.norm(y - a @ x) < 1e-6:
            break
    assert np.linalg.norm(x - xt) < 1e-4, np.linalg.norm(x - xt)
