"""AOT path: lowering produces parseable HLO text + correct meta sidecars,
and the lowered computation is numerically identical to the eager graph."""

from __future__ import annotations

import os
import tempfile

import numpy as np
import jax
import jax.numpy as jnp

from compile import aot, model
from compile.kernels import ref

F32 = np.float32


def test_entry_points_cover_shapes():
    eps = model.entry_points(64, 32, 8, 5)
    names = [e[0] for e in eps]
    assert names == [
        "stoiht_step_n64_b8_s5",
        "iht_step_n64_m32_s5",
        "residual_n64_m32",
    ]
    for _, fn, args, meta in eps:
        assert meta["n"] == 64 and meta["m"] == 32


def test_hlo_text_structure():
    """Every lowered artifact must be HLO text with an ENTRY computation —
    the exact format HloModuleProto::from_text_file on the Rust side parses."""
    for name, fn, args, _meta in model.entry_points(32, 16, 4, 3):
        hlo = aot.lower_entry(fn, args)
        assert "HloModule" in hlo, name
        assert "ENTRY" in hlo, name
        # return_tuple=True: root is a tuple — the Rust side unwraps it.
        assert "tuple(" in hlo or "(f32[" in hlo, name


def test_write_artifact_and_meta_roundtrip():
    with tempfile.TemporaryDirectory() as d:
        paths = aot.build_shape_set(d, 32, 16, 4, 3)
        assert len(paths) == 3
        for p in paths:
            assert os.path.exists(p)
            meta_path = p.replace(".hlo.txt", ".meta")
            kv = {}
            for line in open(meta_path):
                k, _, v = line.partition("=")
                kv[k.strip()] = v.strip()
            assert kv["dtype"] == "f32"
            assert int(kv["n"]) == 32
            assert kv["kind"] in {"stoiht_step", "iht_step", "residual"}


def test_lowered_stoiht_step_matches_eager():
    """Execute the lowered (AOT) computation via jax.export-compatible path
    and compare against the eager oracle — guards against lowering-time
    constant folding or layout bugs."""
    n, m, b, s = 32, 16, 4, 3
    rng = np.random.default_rng(5)
    a = (rng.standard_normal((b, n)) / np.sqrt(m)).astype(F32)
    y = rng.standard_normal((b,)).astype(F32)
    x = rng.standard_normal((n,)).astype(F32)
    tally = (rng.random(n) < 0.2).astype(F32)

    def step_fn(a_, y_, x_, alpha_, t_):
        return model.stoiht_step(a_, y_, x_, alpha_, t_, s=s)

    jitted = jax.jit(step_fn)
    got_x, got_g = jitted(a, y, x, F32(1.0), tally)
    want_x, want_g = ref.stoiht_step_ref(a, y, x, F32(1.0), tally, s)
    np.testing.assert_allclose(np.asarray(got_x), np.asarray(want_x), rtol=1e-5, atol=1e-5)
    np.testing.assert_array_equal(np.asarray(got_g), np.asarray(want_g))


def test_tiled_artifact_lowering():
    """The column-tiled kernel must also lower to plain HLO (interpret mode)."""
    eps = model.entry_points(64, 32, 8, 5, tiled=True, tile_n=16)
    name, fn, args, meta = eps[0]
    hlo = aot.lower_entry(fn, args)
    assert "ENTRY" in hlo
