"""Layer-1 correctness: Pallas kernels vs the pure-jnp oracle.

Hypothesis sweeps shapes (block height, signal dimension, tile width) and
step weights; every case asserts allclose against ``kernels.ref``.  This is
the CORE correctness signal for the compute hot-spot — the AOT artifacts
embed exactly these kernels.
"""

from __future__ import annotations

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.kernels import ref
from compile.kernels.block_grad import block_grad, block_grad_tiled

F32 = np.float32


def _mk(rng, b, n):
    a = (rng.standard_normal((b, n)) / np.sqrt(b)).astype(F32)
    y = rng.standard_normal((b,)).astype(F32)
    x = rng.standard_normal((n,)).astype(F32)
    return a, y, x


@settings(max_examples=20, deadline=None)
@given(
    b=st.integers(1, 24),
    n=st.integers(1, 200),
    alpha=st.floats(-4.0, 4.0, allow_nan=False, width=32),
    seed=st.integers(0, 2**31 - 1),
)
def test_block_grad_matches_ref(b, n, alpha, seed):
    rng = np.random.default_rng(seed)
    a, y, x = _mk(rng, b, n)
    got = np.asarray(block_grad(a, y, x, alpha))
    want = np.asarray(ref.block_grad_ref(a, y, x, F32(alpha)))
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


@settings(max_examples=12, deadline=None)
@given(
    b=st.integers(1, 16),
    tiles=st.integers(1, 8),
    tile_n=st.sampled_from([8, 16, 32, 64]),
    alpha=st.floats(-2.0, 2.0, allow_nan=False, width=32),
    seed=st.integers(0, 2**31 - 1),
)
def test_block_grad_tiled_matches_ref(b, tiles, tile_n, alpha, seed):
    n = tiles * tile_n
    rng = np.random.default_rng(seed)
    a, y, x = _mk(rng, b, n)
    got = np.asarray(block_grad_tiled(a, y, x, alpha, tile_n=tile_n))
    want = np.asarray(ref.block_grad_ref(a, y, x, F32(alpha)))
    np.testing.assert_allclose(got, want, rtol=3e-5, atol=3e-5)


def test_tiled_requires_divisible_n():
    rng = np.random.default_rng(0)
    a, y, x = _mk(rng, 4, 100)
    with pytest.raises(ValueError):
        block_grad_tiled(a, y, x, 1.0, tile_n=64)


def test_block_grad_zero_alpha_is_identity():
    rng = np.random.default_rng(1)
    a, y, x = _mk(rng, 8, 64)
    got = np.asarray(block_grad(a, y, x, 0.0))
    np.testing.assert_allclose(got, x, rtol=0, atol=0)


def test_block_grad_paper_shape():
    """The exact shape lowered into the paper-default artifact."""
    rng = np.random.default_rng(2)
    a, y, x = _mk(rng, 15, 1000)
    got = np.asarray(block_grad(a, y, x, 1.0))
    want = np.asarray(ref.block_grad_ref(a, y, x, F32(1.0)))
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


def test_block_grad_fixed_point():
    """If A_b x == y_b the proxy step is a fixed point for any alpha."""
    rng = np.random.default_rng(3)
    b, n = 6, 40
    a = rng.standard_normal((b, n)).astype(F32)
    x = rng.standard_normal((n,)).astype(F32)
    y = (a @ x).astype(F32)
    got = np.asarray(block_grad(a, y, x, 3.7))
    np.testing.assert_allclose(got, x, rtol=1e-4, atol=1e-4)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_block_grad_linearity_in_y(seed):
    """b(x; y1 + y2) - x == (b(x; y1) - x) + (b(x; y2) - x) at fixed x.

    The proxy update is affine in y — a structural invariant that catches
    indexing errors the pointwise comparison can miss.
    """
    rng = np.random.default_rng(seed)
    b, n = 5, 48
    a = rng.standard_normal((b, n)).astype(F32)
    x = rng.standard_normal((n,)).astype(F32)
    y1 = rng.standard_normal((b,)).astype(F32)
    y2 = rng.standard_normal((b,)).astype(F32)
    d12 = np.asarray(block_grad(a, y1 + y2, x, 1.0)) - x
    d1 = np.asarray(block_grad(a, y1, x, 1.0)) - x
    d2 = np.asarray(block_grad(a, y2, x, 1.0)) - x
    # d(y) = alpha A^T (y - Ax) ⇒ d(y1+y2) = d(y1) + d(y2) + alpha A^T A x... no:
    # d(y1+y2) - d(y1) - d(y2) = alpha A^T ((y1+y2-Ax) - (y1-Ax) - (y2-Ax)) = alpha A^T (Ax)
    corr = np.asarray(a.T @ (a @ x))
    np.testing.assert_allclose(d12, d1 + d2 + corr, rtol=2e-4, atol=2e-4)
