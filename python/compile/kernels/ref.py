"""Pure-jnp reference oracle for every Layer-1 kernel and Layer-2 graph.

This module is the single source of numerical truth: the Pallas kernels in
``block_grad.py`` / ``threshold.py`` and the lowered HLO artifacts are all
checked against these functions by ``python/tests``.  The Rust native
backend is in turn checked against vectors exported from here (see
``tests/test_vectors.py`` which writes ``artifacts/testvectors/*.txt``).

All functions are shape-polymorphic and dtype-preserving so hypothesis can
sweep them.
"""

from __future__ import annotations

import jax.numpy as jnp
from jax import lax


def block_grad_ref(a_blk, y_blk, x, alpha):
    """Proxy step of StoIHT on one measurement block (paper Alg. 1 "proxy").

    Computes ``b = x + alpha * A_b^T (y_b - A_b x)`` where ``alpha`` folds
    the paper's step weight ``gamma / (M p(i))``.

    Args:
      a_blk: ``(b, n)`` block of the measurement matrix.
      y_blk: ``(b,)`` corresponding observations.
      x: ``(n,)`` current iterate.
      alpha: scalar step weight.

    Returns:
      ``(n,)`` proxy vector ``b``.
    """
    r = y_blk - a_blk @ x
    return x + alpha * (a_blk.T @ r)


def residual_ref(a, y, x):
    """Full residual vector ``y - A x`` (used for halting)."""
    return y - a @ x


def residual_norm_ref(a, y, x):
    """Euclidean halting statistic ``||y - A x||_2`` (paper exit criterion)."""
    r = residual_ref(a, y, x)
    return jnp.sqrt(jnp.sum(r * r))


def top_s_mask_ref(v, s):
    """0/1 mask of the ``s`` largest-magnitude entries of ``v``.

    Ties are broken toward the lower index, matching ``jax.lax.top_k`` and
    the Rust ``support::top_s`` implementation.
    """
    n = v.shape[0]
    _, idx = lax.top_k(jnp.abs(v), s)
    return jnp.zeros((n,), v.dtype).at[idx].set(jnp.ones((s,), v.dtype))


def hard_threshold_ref(v, s):
    """IHT thresholding operator ``H_s``: keep the top-s entries, zero rest."""
    return v * top_s_mask_ref(v, s)


def stoiht_step_ref(a_blk, y_blk, x, alpha, tally_mask, s):
    """One full asynchronous-StoIHT estimate step (paper Alg. 2, lines 2-5).

    proxy:    ``b = x + alpha A_b^T (y_b - A_b x)``
    identify: ``gamma_mask = top_s_mask(|b|)``          (Gamma^t)
    union:    ``u = gamma_mask OR tally_mask``          (Gamma^t ∪ T~^t)
    estimate: ``x_next = b|_u``

    ``tally_mask`` is the 0/1 indicator of ``supp_s(phi)`` computed by the
    Rust coordinator from the shared tally; passing a zero mask recovers the
    *synchronous* StoIHT estimate step (Alg. 1) exactly.

    Returns ``(x_next, gamma_mask)`` — the coordinator needs ``Gamma^t`` to
    cast its tally votes.
    """
    b = block_grad_ref(a_blk, y_blk, x, alpha)
    gamma_mask = top_s_mask_ref(b, s)
    union = jnp.maximum(gamma_mask, tally_mask)
    return b * union, gamma_mask


def iht_step_ref(a, y, x, gamma, s):
    """One classical IHT iteration (paper eq. (2)):
    ``x_{t+1} = H_s(x_t + gamma * A^T (y - A x_t))``."""
    g = x + gamma * (a.T @ (y - a @ x))
    return hard_threshold_ref(g, s)
