"""Layer-1 Pallas kernels for the StoIHT proxy hot-spot.

The proxy step ``b = x + alpha * A_b^T (y_b - A_b x)`` dominates the
per-iteration cost of (a)synchronous StoIHT: two dense matvecs against a
``b x n`` block of the measurement matrix.  Two kernels are provided:

* :func:`block_grad` — single-invocation fused kernel.  For the paper shape
  (b=15, n=1000, f32) the whole block is 60 KB, far below VMEM (~16 MB on a
  TPU core), so the natural TPU schedule keeps ``A_b`` resident and fuses
  residual + transpose-matvec + axpy in one pass.  This is the kernel the
  AOT artifacts embed.

* :func:`block_grad_tiled` — column-tiled variant for ``n`` too large for a
  single VMEM block.  The grid walks ``n`` in ``tile_n``-wide column tiles;
  a VMEM scratch accumulates the partial residual across tiles (phase 1),
  and the final tile triggers phase 2 which replays the column tiles for
  the ``A^T r`` update.  This expresses the HBM<->VMEM schedule that a CUDA
  implementation would phrase with threadblocks + shared memory, using
  BlockSpec index maps instead (see README.md, "Hardware adaptation").

Both are lowered with ``interpret=True``: the CPU PJRT plugin cannot run
Mosaic custom-calls, and interpret-mode lowers to plain HLO that any
backend (including the Rust-side PJRT CPU client) executes.  Correctness is
pinned to :mod:`ref` by ``python/tests/test_kernels.py``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


# ---------------------------------------------------------------------------
# Fused single-block kernel (the default for the paper shape).
# ---------------------------------------------------------------------------


def _block_grad_kernel(a_ref, y_ref, x_ref, alpha_ref, o_ref):
    """Fused proxy kernel body.

    VMEM residency: A_b (b x n), x (n), y (b), all read once.
    Compute: one (b x n) @ (n) matvec, one (n x b) @ (b) matvec, one axpy.
    The two matvecs hit the MXU as (1, b) x (b, n) shaped contractions after
    jnp promotes; elementwise runs on the VPU.
    """
    a = a_ref[...]
    x = x_ref[...]
    alpha = alpha_ref[0]
    r = y_ref[...] - a @ x
    o_ref[...] = x + alpha * (r @ a)  # r @ A == A^T r for 1-D r


def block_grad(a_blk, y_blk, x, alpha, *, interpret=True):
    """Proxy step ``x + alpha A_b^T (y_b - A_b x)`` as a fused Pallas call.

    Args:
      a_blk: ``(b, n)`` measurement block.
      y_blk: ``(b,)`` observations for the block.
      x: ``(n,)`` iterate.
      alpha: scalar step weight ``gamma / (M p(i))``.
      interpret: must stay True for CPU-PJRT execution (see module docs).

    Returns:
      ``(n,)`` proxy vector.
    """
    (_, n) = a_blk.shape
    alpha_arr = jnp.asarray(alpha, a_blk.dtype).reshape((1,))
    return pl.pallas_call(
        _block_grad_kernel,
        out_shape=jax.ShapeDtypeStruct((n,), a_blk.dtype),
        interpret=interpret,
    )(a_blk, y_blk, x, alpha_arr)


# ---------------------------------------------------------------------------
# Column-tiled kernel for large n (two-phase residual/update schedule).
# ---------------------------------------------------------------------------


def _block_grad_tiled_kernel(a_ref, y_ref, x_ref, alpha_ref, o_ref, r_ref):
    """Grid body: program (phase, j) handles column tile j of phase `phase`.

    Phase 0 (residual accumulation): walk column tiles, accumulating
        r -= A[:, tile_j] @ x[tile_j]        (init: r = y at j == 0)
    into ``r_ref``, a ``(b,)`` accumulator that is an *output* of the call —
    output blocks persist across grid steps, giving us a VMEM-resident
    accumulator without version-specific scratch APIs.

    Phase 1 (update): replay the column tiles; with ``r`` now complete emit
        o[tile_j] = x[tile_j] + alpha * A[:, tile_j]^T r.

    On TPU the grid executes sequentially per core, so the phase-0 -> phase-1
    dependency through ``r_ref`` is respected; interpret mode preserves the
    same ordering.
    """
    phase = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(phase == 0)
    def _phase1():
        @pl.when(j == 0)
        def _init():
            r_ref[...] = y_ref[...]

        r_ref[...] = r_ref[...] - a_ref[...] @ x_ref[...]

    @pl.when(phase == 1)
    def _phase2():
        o_ref[...] = x_ref[...] + alpha_ref[0] * (r_ref[...] @ a_ref[...])


def block_grad_tiled(a_blk, y_blk, x, alpha, *, tile_n=256, interpret=True):
    """Column-tiled proxy step for ``n`` beyond single-block VMEM capacity.

    The grid is ``(2, n_tiles)``: axis 0 is the residual/update phase (major,
    so every residual tile completes before any update tile runs under the
    row-major grid order), axis 1 walks column tiles.  ``A_b`` column tiles are streamed twice (once
    per phase) while the ``b``-long residual stays VMEM-resident — the same
    traffic pattern as a shared-memory CUDA reduction + broadcast, expressed
    with BlockSpec index maps instead of threadblocks.

    Requires ``n % tile_n == 0`` (callers pad; the AOT path only emits this
    variant for shapes where it divides evenly).
    """
    b, n = a_blk.shape
    if n % tile_n:
        raise ValueError(f"tile_n={tile_n} must divide n={n}")
    n_tiles = n // tile_n
    alpha_arr = jnp.asarray(alpha, a_blk.dtype).reshape((1,))

    out, _r = pl.pallas_call(
        _block_grad_tiled_kernel,
        grid=(2, n_tiles),  # phase-major: all residual tiles before any update tile
        in_specs=[
            pl.BlockSpec((b, tile_n), lambda p, j: (0, j)),   # A_b column tile
            pl.BlockSpec((b,), lambda p, j: (0,)),            # y_b (whole)
            pl.BlockSpec((tile_n,), lambda p, j: (j,)),       # x tile
            pl.BlockSpec((1,), lambda p, j: (0,)),            # alpha
        ],
        out_specs=[
            pl.BlockSpec((tile_n,), lambda p, j: (j,)),       # o tile
            pl.BlockSpec((b,), lambda p, j: (0,)),            # residual accumulator
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n,), a_blk.dtype),
            jax.ShapeDtypeStruct((b,), a_blk.dtype),
        ],
        interpret=interpret,
    )(a_blk, y_blk, x, alpha_arr)
    return out
