"""AOT lowering: JAX graphs -> HLO *text* artifacts for the Rust runtime.

Interchange format is HLO **text**, not a serialized ``HloModuleProto``:
jax >= 0.5 emits protos with 64-bit instruction ids which the published
``xla`` crate's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``);
the text parser reassigns ids and round-trips cleanly (see
/opt/xla-example/README.md).

Each artifact ``<name>.hlo.txt`` gets a ``<name>.meta`` sidecar of
``key = value`` lines that the Rust ``runtime::artifact`` module parses to
discover shapes without re-deriving them from HLO:

    kind = stoiht_step
    n = 1000
    m = 300
    b = 15
    s = 20
    dtype = f32
    inputs = 5
    outputs = 2

Usage (from ``python/``):

    python -m compile.aot --out-dir ../artifacts            # default shape set
    python -m compile.aot --out-dir ../artifacts --n 512 --m 128 --b 8 --s 10
"""

from __future__ import annotations

import argparse
import os

import jax
from jax._src.lib import xla_client as xc

from . import model

# Default artifact shape set: the paper's evaluation shape and a tiny shape
# used by fast Rust integration tests.
DEFAULT_SHAPES = [
    # (n, m, b, s)
    (1000, 300, 15, 20),  # paper §IV
    (32, 16, 4, 3),       # test shape
]


def to_hlo_text(lowered) -> str:
    """StableHLO MLIR -> XlaComputation -> HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_entry(fn, example_args) -> str:
    return to_hlo_text(fn.lower(*example_args))


def write_artifact(out_dir, name, hlo_text, meta):
    path = os.path.join(out_dir, f"{name}.hlo.txt")
    with open(path, "w") as f:
        f.write(hlo_text)
    meta_path = os.path.join(out_dir, f"{name}.meta")
    with open(meta_path, "w") as f:
        for k, v in meta.items():
            f.write(f"{k} = {v}\n")
    return path


def build_shape_set(out_dir, n, m, b, s, tiled=False, tile_n=256):
    """Lower and write the full artifact set for one problem shape."""
    written = []
    for name, fn, example_args, meta in model.entry_points(
        n, m, b, s, tiled=tiled, tile_n=tile_n
    ):
        hlo = lower_entry(fn, example_args)
        meta = dict(meta)
        meta["dtype"] = "f32"
        meta["inputs"] = len(example_args)
        meta["outputs"] = 2 if meta["kind"] == "stoiht_step" else 1
        meta["tiled"] = int(tiled)
        path = write_artifact(out_dir, name, hlo, meta)
        written.append(path)
        print(f"  wrote {path} ({len(hlo)} chars)")
    return written


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--out", default=None, help="stamp file to touch on success")
    ap.add_argument("--n", type=int, default=None)
    ap.add_argument("--m", type=int, default=None)
    ap.add_argument("--b", type=int, default=None)
    ap.add_argument("--s", type=int, default=None)
    ap.add_argument("--tiled", action="store_true", help="use the column-tiled kernel")
    ap.add_argument("--tile-n", type=int, default=256)
    args = ap.parse_args()

    out_dir = args.out_dir
    os.makedirs(out_dir, exist_ok=True)

    if args.n is not None:
        shapes = [(args.n, args.m, args.b, args.s)]
    else:
        shapes = DEFAULT_SHAPES

    print(f"jax {jax.__version__} lowering {len(shapes)} shape set(s) -> {out_dir}")
    for n, m, b, s in shapes:
        assert m % b == 0, f"block size {b} must divide m={m}"
        print(f"shape n={n} m={m} b={b} s={s} tiled={args.tiled}")
        build_shape_set(out_dir, n, m, b, s, tiled=args.tiled, tile_n=args.tile_n)

    if args.out:
        with open(args.out, "w") as f:
            f.write("ok\n")


if __name__ == "__main__":
    main()
