"""Export deterministic oracle test vectors for the Rust test suite.

The Rust native backend re-implements the StoIHT step in f64; its unit
tests load these vectors (plain-text, one value per line) and assert
agreement with the JAX oracle to f32 precision.  Run by ``make artifacts``.

Format of ``artifacts/testvectors/<case>.txt``::

    # key = value header lines
    # then one section per tensor:
    tensor <name> <len>
    v0
    v1
    ...
"""

from __future__ import annotations

import os
import sys

import numpy as np

from .kernels import ref

F32 = np.float32


def _emit(f, name, arr):
    arr = np.asarray(arr, dtype=np.float64).reshape(-1)
    f.write(f"tensor {name} {arr.size}\n")
    for v in arr:
        f.write(f"{float(v)!r}\n")


def export_case(out_dir, case_id, n, m, b, s, seed):
    rng = np.random.default_rng(seed)
    M = m // b
    a = (rng.standard_normal((m, n)) / np.sqrt(m)).astype(F32)
    x_true = np.zeros(n, F32)
    supp = np.sort(rng.choice(n, s, replace=False))
    x_true[supp] = rng.standard_normal(s).astype(F32)
    y = (a @ x_true).astype(F32)
    x = rng.standard_normal(n).astype(F32) * 0.1
    tally = np.zeros(n, F32)
    tally[np.sort(rng.choice(n, s, replace=False))] = 1.0
    blk = int(rng.integers(M))
    ab = a[blk * b : (blk + 1) * b]
    yb = y[blk * b : (blk + 1) * b]
    alpha = F32(1.0)

    bvec = np.asarray(ref.block_grad_ref(ab, yb, x, alpha))
    x_next, gmask = ref.stoiht_step_ref(ab, yb, x, alpha, tally, s)
    rnorm = float(ref.residual_norm_ref(a, y, x))
    iht_next = np.asarray(ref.iht_step_ref(a, y, x, F32(0.8), s))

    path = os.path.join(out_dir, f"{case_id}.txt")
    with open(path, "w") as f:
        f.write(f"# n = {n}\n# m = {m}\n# b = {b}\n# s = {s}\n")
        f.write(f"# block = {blk}\n# alpha = 1.0\n# gamma_iht = 0.8\n")
        f.write(f"# residual_norm = {float(rnorm)!r}\n")
        _emit(f, "a", a)            # row-major (m, n)
        _emit(f, "y", y)
        _emit(f, "x", x)
        _emit(f, "x_true", x_true)
        _emit(f, "tally_mask", tally)
        _emit(f, "proxy", bvec)
        _emit(f, "x_next", np.asarray(x_next))
        _emit(f, "gamma_mask", np.asarray(gmask))
        _emit(f, "iht_next", iht_next)
    return path


def main():
    out_dir = sys.argv[1] if len(sys.argv) > 1 else "../artifacts/testvectors"
    os.makedirs(out_dir, exist_ok=True)
    cases = [
        ("case_small", 32, 16, 4, 3, 101),
        ("case_mid", 128, 64, 8, 6, 202),
        ("case_paper", 1000, 300, 15, 20, 303),
    ]
    for cid, n, m, b, s, seed in cases:
        p = export_case(out_dir, cid, n, m, b, s, seed)
        print(f"  wrote {p}")


if __name__ == "__main__":
    main()
