"""Layer-2 JAX compute graphs for (asynchronous) StoIHT.

These are the functions the Rust coordinator executes on its solve path via
AOT-lowered HLO artifacts (see :mod:`compile.aot`).  Each graph calls the
Layer-1 Pallas kernel for its hot-spot and keeps the support logic (top-k,
union, projection) in plain XLA ops so the whole step lowers to a single
fused module.

Graph inventory (shapes are static at lowering time, one artifact per shape):

* :func:`stoiht_step` — one full Alg.-2 iteration body: proxy + identify +
  union-with-tally + estimate.  Inputs ``(A_b, y_b, x, alpha, tally_mask)``,
  outputs ``(x_next, gamma_mask)``.  With ``tally_mask = 0`` this is exactly
  the synchronous Alg.-1 step.
* :func:`residual_norm` — halting statistic ``||y - A x||_2`` over the full
  measurement matrix.
* :func:`iht_step` — classical IHT iteration (paper eq. (2)), the
  sequential baseline, AOT-compiled so the Rust side can run IHT through
  PJRT too.

The paper's per-core weight ``alpha = gamma / (M p(i))`` is a runtime input
(scalar tensor) so one artifact serves any sampling distribution.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from .kernels.block_grad import block_grad, block_grad_tiled


def _top_s_mask(v, s):
    """0/1 mask (dtype of ``v``) of the s largest-|.| entries of ``v``.

    Deliberately avoids ``lax.top_k``: jax >= 0.6 lowers it to the ``topk``
    HLO instruction with a ``largest=`` attribute that the xla_extension
    0.5.1 text parser (our AOT interchange target) rejects. Instead we sort
    the magnitudes (plain HLO ``sort``), read the s-th largest as a
    threshold, and build the mask with a cumulative count so that ties at
    the threshold are broken toward the **lower index** — bit-identical to
    ``lax.top_k`` and to the Rust `support::top_s`.
    """
    n = v.shape[0]
    a = jnp.abs(v)
    sorted_a = lax.sort(a, dimension=0)  # ascending
    thr = sorted_a[n - s]  # s-th largest magnitude
    gt = (a > thr).astype(v.dtype)
    need = jnp.asarray(s, v.dtype) - jnp.sum(gt)  # ties to admit
    eq = (a == thr).astype(v.dtype)
    rank_among_eq = jnp.cumsum(eq)  # 1-based, in index order
    return gt + eq * (rank_among_eq <= need).astype(v.dtype)


def stoiht_step(a_blk, y_blk, x, alpha, tally_mask, *, s, tiled=False, tile_n=256):
    """One asynchronous-StoIHT iteration body (paper Alg. 2 lines 2–5).

    proxy:     ``b = x + alpha * A_b^T (y_b - A_b x)``   (Pallas kernel)
    identify:  ``gamma = supp_s(b)``                      (lax.top_k)
    estimate:  ``x_next = b|_{gamma ∪ supp(tally_mask)}``

    Args:
      a_blk: ``(b, n)`` measurement block selected by the coordinator.
      y_blk: ``(b,)`` observations.
      x: ``(n,)`` the core's local iterate.
      alpha: scalar ``gamma_step / (M p(i))``.
      tally_mask: ``(n,)`` 0/1 indicator of ``supp_s(phi)`` (zeros ⇒ Alg. 1).
      s: static sparsity level (baked into the artifact).
      tiled: lower the column-tiled kernel instead of the fused one.

    Returns:
      ``(x_next, gamma_mask)`` — the coordinator casts tally votes on the
      nonzeros of ``gamma_mask``.
    """
    kern = block_grad_tiled if tiled else block_grad
    kw = {"tile_n": tile_n} if tiled else {}
    b = kern(a_blk, y_blk, x, alpha, **kw)
    gamma_mask = _top_s_mask(b, s)
    union = jnp.maximum(gamma_mask, tally_mask)
    return b * union, gamma_mask


def residual_norm(a, y, x):
    """Halting statistic ``||y - A x||_2`` (full measurement matrix)."""
    r = y - a @ x
    return jnp.sqrt(jnp.sum(r * r))


def iht_step(a, y, x, gamma, *, s):
    """Classical IHT iteration (paper eq. (2)): ``H_s(x + gamma A^T(y-Ax))``.

    Uses the same Pallas proxy kernel with the full matrix as one "block",
    so IHT and StoIHT share the Layer-1 hot-spot implementation.
    """
    g = block_grad(a, y, x, gamma)
    return g * _top_s_mask(g, s)


# ---------------------------------------------------------------------------
# Lowering entry points — one (name, fn, example_args) triple per artifact.
# ---------------------------------------------------------------------------


def entry_points(n, m, b, s, dtype=jnp.float32, tiled=False, tile_n=256):
    """The artifact set for one problem shape.

    Returns a list of ``(name, jitted_fn, example_args)`` with static shapes
    baked in; :mod:`compile.aot` lowers each to HLO text.
    """
    f = dtype
    vec = lambda k: jax.ShapeDtypeStruct((k,), f)  # noqa: E731
    mat = lambda r, c: jax.ShapeDtypeStruct((r, c), f)  # noqa: E731
    scal = jax.ShapeDtypeStruct((), f)

    def step_fn(a_blk, y_blk, x, alpha, tally_mask):
        return stoiht_step(
            a_blk, y_blk, x, alpha, tally_mask, s=s, tiled=tiled, tile_n=tile_n
        )

    def iht_fn(a, y, x, gamma):
        return iht_step(a, y, x, gamma, s=s)

    def resid_fn(a, y, x):
        return (residual_norm(a, y, x),)

    return [
        (
            f"stoiht_step_n{n}_b{b}_s{s}",
            jax.jit(step_fn),
            (mat(b, n), vec(b), vec(n), scal, vec(n)),
            {"kind": "stoiht_step", "n": n, "m": m, "b": b, "s": s},
        ),
        (
            f"iht_step_n{n}_m{m}_s{s}",
            jax.jit(iht_fn),
            (mat(m, n), vec(m), vec(n), scal),
            {"kind": "iht_step", "n": n, "m": m, "b": m, "s": s},
        ),
        (
            f"residual_n{n}_m{m}",
            jax.jit(resid_fn),
            (mat(m, n), vec(m), vec(n)),
            {"kind": "residual", "n": n, "m": m, "b": m, "s": s},
        ),
    ]
