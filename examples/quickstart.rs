//! Quickstart: recover a sparse signal from compressed measurements with
//! asynchronous StoIHT — the 60-second tour of the public API.
//!
//!     cargo run --release --example quickstart

use astir::async_runtime::{run_async, AsyncOpts};
use astir::problem::ProblemSpec;
use astir::rng::Rng;

fn main() {
    // 1. A compressed-sensing instance: the paper's §IV configuration.
    //    y = A x + z with A ~ N(0, 1/m), x exactly s-sparse, z = 0.
    let spec = ProblemSpec::paper(); // n=1000, m=300, b=15, s=20
    let mut rng = Rng::seed_from(2017);
    let problem = spec.generate(&mut rng);
    println!(
        "problem: n={} m={} blocks={} s={} (true support: {:?}…)",
        spec.n,
        spec.m,
        spec.num_blocks(),
        spec.s,
        &problem.support[..4.min(problem.support.len())]
    );

    // 2. Solve with 8 worker threads sharing a lock-free tally vector
    //    (the paper's Algorithm 2 on real cores).
    let opts = AsyncOpts::default(); // gamma=1, tol=1e-7, cap 1500
    let out = run_async(&problem, 8, &opts, 42);

    // 3. Inspect the outcome.
    println!(
        "converged={} in {:?} (worker {} exited first)",
        out.converged,
        out.wall,
        out.exit_core.unwrap_or(usize::MAX)
    );
    println!("residual ||y - Ax||  = {:.3e}", out.residual);
    println!("recovery ||x - x*||  = {:.3e}", out.final_error);
    println!("local iterations/core: {:?}", out.local_iters);
    assert!(out.converged, "quickstart should converge");

    // 4. The recovered support is exactly the planted one.
    let support = astir::support::support_of(&out.x);
    let acc = astir::support::accuracy(&support, &problem.support);
    println!("support accuracy     = {acc:.2}");
}
