//! Inconsistent reads of the shared tally (paper §III ¶3, ablation A2):
//! inject per-coordinate stale reads into the discrete-time simulator and
//! measure the cost — the paper *hopes* the tally is robust; this example
//! quantifies it.
//!
//!     cargo run --release --example inconsistent_reads [trials]

use astir::metrics::stats;
use astir::problem::ProblemSpec;
use astir::rng::Rng;
use astir::sim::{simulate, SimOpts, SpeedSchedule};

fn main() {
    let trials: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(20);
    let spec = ProblemSpec::paper();
    let cores = 8;
    println!("asynchronous StoIHT, {cores} simulated cores, {trials} trials per point");
    println!("stale_prob = probability each coordinate of a tally read is one step old\n");
    println!("{:>10} {:>12} {:>10} {:>8}", "stale_prob", "steps-mean", "steps-std", "conv");

    for prob in [0.0, 0.1, 0.25, 0.5, 0.75, 1.0] {
        let mut steps = Vec::new();
        let mut conv = 0;
        for t in 0..trials {
            let p = spec.generate(&mut Rng::seed_from(t as u64));
            let opts = SimOpts { stale_read_prob: prob, max_steps: 3000, ..Default::default() };
            let sim_rng = &mut Rng::seed_from(70 + t as u64);
            let out = simulate(&p, cores, &SpeedSchedule::AllFast, &opts, sim_rng);
            steps.push(out.steps as f64);
            conv += out.converged as usize;
        }
        let st = stats(&steps);
        println!(
            "{:>10} {:>12.0} {:>10.0} {:>5}/{trials}",
            prob, st.mean, st.std, conv
        );
    }

    println!("\nEven fully-stale reads (prob = 1: every coordinate one step old)");
    println!("only shift the curve — the tally is used passively, so stale");
    println!("support votes degrade the estimate's freshness, not correctness.");
}
