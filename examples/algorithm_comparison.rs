//! Compare every recovery algorithm in the crate on one problem instance:
//! IHT, StoIHT, OMP, CoSaMP, StoGradMP, plus the Fig.-1 oracle-assisted
//! StoIHT — iterations, wallclock, residual, and recovery error.
//!
//!     cargo run --release --example algorithm_comparison

use std::time::Instant;

use astir::algorithms::{
    cosamp, iht, make_oracle, omp, stogradmp, stoiht, stoiht_with_oracle, GreedyOpts,
};
use astir::problem::ProblemSpec;
use astir::rng::Rng;

fn main() {
    let spec = ProblemSpec::paper();
    let mut rng = Rng::seed_from(7);
    let p = spec.generate(&mut rng);
    let opts = GreedyOpts::default();

    println!(
        "n={} m={} b={} s={} gamma={} tol={:.0e}\n",
        spec.n, spec.m, spec.b, spec.s, opts.gamma, opts.tolerance
    );
    println!(
        "{:<22} {:>7} {:>10} {:>12} {:>12}",
        "algorithm", "iters", "wall", "residual", "error"
    );

    let report = |name: &str, f: &mut dyn FnMut() -> astir::algorithms::RunResult| {
        let t0 = Instant::now();
        let r = f();
        let dt = t0.elapsed();
        println!(
            "{:<22} {:>7} {:>10.2?} {:>12.3e} {:>12.3e}",
            name,
            r.iters,
            dt,
            r.residual,
            p.recovery_error(&r.x)
        );
    };

    report("IHT", &mut || iht(&p, &opts));
    report("StoIHT", &mut || stoiht(&p, &opts, &mut Rng::seed_from(100)));
    report("OMP", &mut || omp(&p, &opts));
    report("CoSaMP", &mut || {
        cosamp(&p, &GreedyOpts { max_iters: 100, ..opts.clone() })
    });
    report("StoGradMP", &mut || {
        stogradmp(&p, &GreedyOpts { max_iters: 200, ..opts.clone() }, &mut Rng::seed_from(101))
    });

    // Fig.-1 oracle variants: union the estimate step with a support guess
    // of accuracy alpha.
    for alpha in [0.5, 1.0] {
        let oracle = make_oracle(&p, alpha, &mut Rng::seed_from(55));
        let name = format!("StoIHT oracle α={alpha}");
        report(&name, &mut || {
            stoiht_with_oracle(&p, &opts, &mut Rng::seed_from(100), &oracle)
        });
    }

    println!("\nNote: CoSaMP/StoGradMP/OMP converge in few (expensive, LS-solve)");
    println!("iterations; IHT/StoIHT take many cheap gradient steps. The async");
    println!("runtime (examples/async_speedup.rs) parallelizes the latter.");
}
