//! END-TO-END DRIVER — proves the full three-layer stack composes on a
//! real workload:
//!
//!   Layer 1  Pallas `block_grad` kernel (interpret mode)      [python]
//!   Layer 2  JAX `stoiht_step` graph, AOT-lowered to HLO text [python]
//!   bridge   `artifacts/*.hlo.txt` + `.meta` sidecars
//!   Layer 3  THIS BINARY: Rust coordinator loads the HLO via the PJRT C
//!            API and runs (a) sequential StoIHT and (b) multi-worker
//!            asynchronous StoIHT with a lock-free shared tally, where
//!            every proxy/identify/estimate step executes inside XLA.
//!
//! Requires `make artifacts`. Reports the paper's headline metric —
//! steps-to-exit and wallclock vs cores — plus PJRT-vs-native agreement.
//!
//!     cargo run --release --example e2e_pjrt

use std::time::Instant;

use astir::async_runtime::{run_async_with, AsyncOpts, BackendStep};
use astir::backend::{Backend, NativeBackend, PjrtBackend};
use astir::problem::ProblemSpec;
use astir::rng::Rng;

fn main() -> astir::error::Result<()> {
    // The artifact set ships two shapes; the tiny one keeps this example
    // fast under interpret-lowered XLA while exercising every layer.
    // Switch to ProblemSpec::paper() to run the full paper shape.
    let spec = ProblemSpec { n: 32, m: 16, b: 4, s: 3, ..ProblemSpec::tiny() };
    let mut rng = Rng::seed_from(99);
    let problem = spec.generate(&mut rng);

    println!("== layer check: PJRT artifact vs native kernel on one step ==");
    let mut native = NativeBackend::new();
    let mut pjrt = PjrtBackend::from_default_dir()?;
    println!("PJRT platform: {}", pjrt.runtime().platform());
    let x0: Vec<f64> = (0..spec.n).map(|_| 0.1 * rng.gauss()).collect();
    let mask = vec![0.0; spec.n];
    let (nx, ng) = native.stoiht_step(&problem, 0, &x0, 1.0, &mask)?;
    let (px, pg) = pjrt.stoiht_step(&problem, 0, &x0, 1.0, &mask)?;
    let max_diff = nx
        .iter()
        .zip(&px)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f64, f64::max);
    println!("gamma sets equal: {} | max |Δx| = {max_diff:.2e} (f32 artifact)", ng == pg);
    assert!(ng == pg && max_diff < 1e-4);

    println!("\n== sequential StoIHT with every step on PJRT ==");
    let t0 = Instant::now();
    let mut x = vec![0.0f64; spec.n];
    let mut iters = 0;
    let zero_mask = vec![0.0f64; spec.n];
    let mut solver_rng = Rng::seed_from(5);
    while iters < 1500 {
        let block = solver_rng.below(spec.num_blocks());
        let (xn, _) = pjrt.stoiht_step(&problem, block, &x, 1.0, &zero_mask)?;
        x = xn;
        iters += 1;
        if pjrt.residual_norm(&problem, &x)? < 1e-5 {
            break;
        }
    }
    println!(
        "iters={iters} wall={:.1?} residual={:.3e} error={:.3e}",
        t0.elapsed(),
        problem.residual_norm(&x),
        problem.recovery_error(&x)
    );
    assert!(problem.recovery_error(&x) < 1e-2);

    println!("\n== asynchronous StoIHT: workers drive PJRT executables ==");
    println!("{:>6} {:>8} {:>12} {:>12} {:>12}", "cores", "conv", "win-iters", "wall", "error");
    for cores in [1usize, 2, 4] {
        let opts = AsyncOpts {
            tolerance: 1e-5, // f32 artifacts
            max_local_iters: 1500,
            ..Default::default()
        };
        // Each worker thread constructs its own PJRT runtime (the client is
        // not Send); the factory runs inside the spawned thread. Kernels
        // bake their step size at construction, so gamma is threaded here.
        let gamma = opts.gamma;
        let out = run_async_with(&problem, cores, &opts, 31 + cores as u64, move |p| {
            let backend = PjrtBackend::from_default_dir().expect("artifacts available");
            Box::new(BackendStep::new(p, backend).with_gamma(gamma))
        });
        let win_iters = out
            .exit_core
            .map(|w| out.local_iters[w].to_string())
            .unwrap_or_else(|| "-".into());
        println!(
            "{:>6} {:>8} {:>12} {:>12.1?} {:>12.3e}",
            cores, out.converged, win_iters, out.wall, out.final_error
        );
        assert!(out.converged, "PJRT async run must converge");
    }

    println!("\nAll three layers compose: Pallas kernel -> JAX graph -> HLO text ->");
    println!("PJRT executable -> Rust async coordinator. Python never ran here.");
    Ok(())
}
