//! Fig.-2-style sweep on *real threads*: wallclock and steps-to-exit of
//! asynchronous StoIHT vs core count, under the all-fast and half-slow
//! schedules — the measured version of what the paper simulates.
//!
//!     cargo run --release --example async_speedup [trials]

use astir::algorithms::{stoiht, GreedyOpts};
use astir::async_runtime::{run_async, AsyncOpts};
use astir::metrics::stats;
use astir::problem::ProblemSpec;
use astir::rng::Rng;
use astir::sim::SpeedSchedule;

fn main() {
    let trials: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(5);
    let spec = ProblemSpec::paper();
    let hw = std::thread::available_parallelism().map(|v| v.get()).unwrap_or(4);
    println!("hardware threads: {hw}; trials per point: {trials}\n");

    // Sequential baseline.
    let mut seq_iters = Vec::new();
    let mut seq_wall = Vec::new();
    for t in 0..trials {
        let p = spec.generate(&mut Rng::seed_from(t as u64));
        let t0 = std::time::Instant::now();
        let r = stoiht(&p, &GreedyOpts::default(), &mut Rng::seed_from(900 + t as u64));
        seq_wall.push(t0.elapsed().as_secs_f64());
        seq_iters.push(r.iters as f64);
    }
    println!(
        "sequential StoIHT: {:.0} iters (mean), {:.1} ms (mean wall)",
        stats(&seq_iters).mean,
        1e3 * stats(&seq_wall).mean
    );

    for (label, schedule) in [
        ("all-fast", SpeedSchedule::AllFast),
        ("half-slow(4)", SpeedSchedule::HalfSlow { period: 4 }),
    ] {
        println!("\nschedule: {label}");
        println!("{:>6} {:>12} {:>12} {:>10}", "cores", "iters(win)", "wall-mean", "speedup");
        for cores in [1usize, 2, 4, 8] {
            let mut walls = Vec::new();
            let mut iters = Vec::new();
            let mut conv = 0;
            for t in 0..trials {
                let p = spec.generate(&mut Rng::seed_from(t as u64));
                let opts = AsyncOpts { schedule: schedule.clone(), ..Default::default() };
                let out = run_async(&p, cores, &opts, 4000 + t as u64);
                if out.converged {
                    conv += 1;
                    walls.push(out.wall.as_secs_f64());
                    let win = out.exit_core.unwrap();
                    iters.push(out.local_iters[win] as f64);
                }
            }
            if walls.is_empty() {
                println!("{cores:>6} (no converged trials)");
                continue;
            }
            let wall_mean = stats(&walls).mean;
            println!(
                "{:>6} {:>12.0} {:>10.1}ms {:>9.2}x  ({conv}/{trials} converged)",
                cores,
                stats(&iters).mean,
                1e3 * wall_mean,
                stats(&seq_wall).mean / wall_mean
            );
        }
    }
    println!("\n(speedup = sequential wall / async wall; the winner's iteration");
    println!("count shows the algorithmic effect, wallclock shows the system one)");
}
